//! The GreeDi protocol — Algorithm 2 (cardinality) and Algorithm 3 (general
//! hereditary constraints) of the paper, executed over the simulated
//! MapReduce runtime.
//!
//! Round 1 (map): partition V over m machines; each runs the configured
//! black-box algorithm (lazy greedy by default) on its shard with budget κ
//! (= α·k, the paper's over-selection knob) or constraint ζ.
//!
//! Round 2 (reduce): merge the m candidate sets into B (≤ m·κ elements —
//! the only communication), run the black box again with budget k, and
//! return the better of { best round-1 set, round-2 set }.
//!
//! In **local mode** (paper §4.5, decomposable objectives) round 1 evaluates
//! the objective restricted to each machine's shard and round 2 on a random
//! ⌈n/m⌉-element window; reported values are always re-evaluated under the
//! true global objective.

use super::metrics::RunMetrics;
use super::Problem;
use crate::algorithms;
use crate::constraints::cardinality::Cardinality;
use crate::constraints::Constraint;
use crate::mapreduce::partition::{balanced_partition, contiguous_partition, random_partition};
use crate::mapreduce::{JobReport, MapReduce};
use crate::util::rng::Rng;

/// How the ground set is spread over machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Uniform random assignment (the theory's assumption).
    Random,
    /// Shuffled round-robin (equal shard sizes).
    Balanced,
    /// Contiguous slices (no randomization — ablation / worst case).
    Contiguous,
}

/// GreeDi configuration.
#[derive(Debug, Clone)]
pub struct GreediConfig {
    /// Number of machines m.
    pub m: usize,
    /// Final solution budget k.
    pub k: usize,
    /// Per-machine budget κ (Algorithm 2 allows κ ≠ k; α = κ/k).
    pub kappa: usize,
    /// Decomposable local evaluation (paper §4.5).
    pub local_eval: bool,
    /// Black-box algorithm name (see `algorithms::by_name`).
    pub algorithm: String,
    /// OS threads for the simulated cluster.
    pub threads: usize,
    pub partition: PartitionStrategy,
}

impl GreediConfig {
    pub fn new(m: usize, k: usize) -> Self {
        GreediConfig {
            m: m.max(1),
            k,
            kappa: k,
            local_eval: false,
            algorithm: "lazy".to_string(),
            threads: 1,
            partition: PartitionStrategy::Random,
        }
    }

    /// Set κ = ⌈α·k⌉ (the paper sweeps α ∈ {κ/k}).
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.kappa = ((alpha * self.k as f64).round() as usize).max(1);
        self
    }

    pub fn local(mut self) -> Self {
        self.local_eval = true;
        self
    }

    pub fn algorithm(mut self, name: &str) -> Self {
        assert!(algorithms::by_name(name).is_some(), "unknown algorithm {name}");
        self.algorithm = name.to_string();
        self
    }

    pub fn partition(mut self, p: PartitionStrategy) -> Self {
        self.partition = p;
        self
    }

    pub fn threads(mut self, t: usize) -> Self {
        self.threads = t.max(1);
        self
    }
}

/// The two-round distributed maximizer.
pub struct Greedi {
    pub cfg: GreediConfig,
}

impl Greedi {
    pub fn new(cfg: GreediConfig) -> Self {
        Greedi { cfg }
    }

    /// Algorithm 2: cardinality constraints (κ per machine, k final).
    pub fn run(&self, problem: &dyn Problem, seed: u64) -> RunMetrics {
        let r1 = Cardinality::new(self.cfg.kappa);
        let r2 = Cardinality::new(self.cfg.k);
        self.run_constrained(problem, &r1, &r2, seed)
    }

    /// Algorithm 3: arbitrary hereditary constraints per round. For the
    /// general setting pass the same ζ for both rounds.
    pub fn run_constrained(
        &self,
        problem: &dyn Problem,
        round1: &dyn Constraint,
        round2: &dyn Constraint,
        seed: u64,
    ) -> RunMetrics {
        let cfg = &self.cfg;
        let base_rng = Rng::new(seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let shards = match cfg.partition {
            PartitionStrategy::Random => random_partition(&ground, cfg.m, &mut rng),
            PartitionStrategy::Balanced => balanced_partition(&ground, cfg.m, &mut rng),
            PartitionStrategy::Contiguous => contiguous_partition(&ground, cfg.m),
        };

        let engine = MapReduce::new(cfg.threads);
        let mut job = JobReport::default();

        // ---- Round 1: per-machine black box ------------------------------
        let local_eval = cfg.local_eval;
        let algo_name = cfg.algorithm.clone();
        let inputs: Vec<(usize, Vec<usize>)> = shards.into_iter().enumerate().collect();
        let (round1_results, stage1) = engine.run_stage(inputs, |_, (i, shard)| {
            let mut task_rng = base_rng.fork(1000 + i as u64);
            let algo = algorithms::by_name(&algo_name).expect("algorithm");
            let obj = if local_eval {
                problem.local(&shard, &mut task_rng)
            } else {
                problem.global()
            };
            algo.maximize(obj.as_ref(), &shard, round1, &mut task_rng)
        });
        job.stages.push(stage1);

        let mut oracle_calls: u64 = round1_results.iter().map(|r| r.oracle_calls).sum();

        // Union of round-1 candidate sets = the only shuffled data.
        let mut merged: Vec<usize> = Vec::new();
        for r in &round1_results {
            merged.extend_from_slice(&r.solution);
        }
        merged.sort_unstable();
        merged.dedup();
        job.record_shuffle(merged.len());

        // ---- Round 2: merge machine --------------------------------------
        let candidates: Vec<Vec<usize>> =
            round1_results.iter().map(|r| r.solution.clone()).collect();
        let merged_for_task = merged.clone();
        let algo_name2 = cfg.algorithm.clone();
        let m = cfg.m;
        let (mut round2_out, stage2) = engine.run_stage(vec![()], |_, ()| {
            let mut task_rng = base_rng.fork(2000);
            let obj = if local_eval {
                problem.merge(m, &mut task_rng)
            } else {
                problem.global()
            };
            let algo = algorithms::by_name(&algo_name2).expect("algorithm");
            let run_b = algo.maximize(obj.as_ref(), &merged_for_task, round2, &mut task_rng);
            let mut extra_oracle = run_b.oracle_calls;

            // A^gc_max: the best round-1 set under this round's objective F,
            // trimmed to feasibility under the round-2 constraint if κ > k
            // (prefix-feasible by heredity: keep the greedy selection order).
            let mut best: Option<(Vec<usize>, f64)> = None;
            for cand in &candidates {
                let mut trimmed: Vec<usize> = Vec::new();
                for &e in cand {
                    if round2.can_add(&trimmed, e) {
                        trimmed.push(e);
                    }
                }
                let v = obj.eval(&trimmed);
                extra_oracle += trimmed.len() as u64;
                if best.as_ref().map(|(_, bv)| v > *bv).unwrap_or(true) {
                    best = Some((trimmed, v));
                }
            }
            let (max_sol, max_val) = best.unwrap_or((Vec::new(), f64::NEG_INFINITY));
            let winner = if run_b.value >= max_val {
                run_b.solution
            } else {
                max_sol
            };
            (winner, extra_oracle)
        });
        job.stages.push(stage2);
        let (solution, extra) = round2_out.pop().unwrap();
        oracle_calls += extra;

        // Final reported value: always the true global objective.
        let value = problem.global().eval(&solution);

        RunMetrics {
            name: format!(
                "greedi[m={},k={},κ={}{}]",
                cfg.m,
                cfg.k,
                cfg.kappa,
                if cfg.local_eval { ",local" } else { "" }
            ),
            solution,
            value,
            oracle_calls,
            job,
            rounds: 2,
        }
    }
}

/// Centralized reference run (one machine, full ground set, budget k) —
/// the denominator of every ratio the paper reports.
pub fn centralized(
    problem: &dyn Problem,
    k: usize,
    algorithm: &str,
    seed: u64,
) -> RunMetrics {
    let engine = MapReduce::new(1);
    let mut job = JobReport::default();
    let ground = problem.ground();
    let base_rng = Rng::new(seed);
    let (mut out, stage) = engine.run_stage(vec![ground], |_, g| {
        let mut rng = base_rng.fork(1);
        let algo = algorithms::by_name(algorithm).expect("algorithm");
        let obj = problem.global();
        algo.maximize(obj.as_ref(), &g, &Cardinality::new(k), &mut rng)
    });
    job.stages.push(stage);
    let r = out.pop().unwrap();
    RunMetrics {
        name: format!("centralized[k={k}]"),
        value: problem.global().eval(&r.solution),
        solution: r.solution,
        oracle_calls: r.oracle_calls,
        job,
        rounds: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CutProblem, FacilityProblem, InfoGainProblem, OpaqueProblem};
    use crate::data::graph::social_network;
    use crate::data::synth::{gaussian_blobs, parkinsons_like, SynthConfig};
    use crate::objective::entropy_worstcase::EntropyWorstCase;
    use std::sync::Arc;

    #[test]
    fn greedi_close_to_centralized_on_facility() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 41));
        let p = FacilityProblem::new(&ds);
        let central = centralized(&p, 10, "lazy", 7);
        let run = Greedi::new(GreediConfig::new(5, 10)).run(&p, 7);
        assert!(run.solution.len() <= 10);
        let ratio = run.ratio_vs(central.value);
        assert!(ratio > 0.9, "ratio {ratio}");
        assert!(ratio <= 1.0 + 1e-9);
        assert_eq!(run.rounds, 2);
    }

    #[test]
    fn greedi_local_mode_still_competitive() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 42));
        let p = FacilityProblem::new(&ds);
        let central = centralized(&p, 10, "lazy", 3);
        let run = Greedi::new(GreediConfig::new(5, 10).local()).run(&p, 3);
        let ratio = run.ratio_vs(central.value);
        assert!(ratio > 0.8, "local ratio {ratio}");
    }

    #[test]
    fn kappa_over_selection_helps_or_equals() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 43));
        let p = FacilityProblem::new(&ds);
        let base = Greedi::new(GreediConfig::new(4, 8)).run(&p, 5);
        let over = Greedi::new(GreediConfig::new(4, 8).alpha(2.0)).run(&p, 5);
        assert!(over.solution.len() <= 8);
        assert!(over.value >= base.value * 0.98, "{} vs {}", over.value, base.value);
    }

    #[test]
    fn infogain_greedi_ratio() {
        let ds = Arc::new(parkinsons_like(150, 10, 44));
        let p = InfoGainProblem::paper_params(&ds);
        let central = centralized(&p, 8, "lazy", 2);
        let run = Greedi::new(GreediConfig::new(5, 8)).run(&p, 2);
        assert!(run.ratio_vs(central.value) > 0.9);
    }

    #[test]
    fn nonmonotone_cut_via_random_greedy() {
        let g = Arc::new(social_network(120, 800, 4));
        let p = CutProblem::new(&g);
        let run = Greedi::new(GreediConfig::new(4, 10).algorithm("random_greedy").local())
            .run(&p, 6);
        assert!(run.value >= 0.0);
        assert!(run.solution.len() <= 10);
    }

    #[test]
    fn worst_case_instance_respects_theorem3_bound() {
        // On the adversarial instance with contiguous partitioning the
        // distributed value can degrade but never below OPT/min(m,k)
        // multiplied by the greedy factor — and never above OPT.
        let (m, k) = (4, 4);
        let f = EntropyWorstCase::new(m, k);
        let p = OpaqueProblem::new(&f);
        let opt = f.optimal_value(k);
        let run = Greedi::new(
            GreediConfig::new(m, k).partition(PartitionStrategy::Contiguous),
        )
        .run(&p, 1);
        assert!(run.value <= opt + 1e-9);
        let bound = (1.0 - (-1.0f64).exp()) / (m.min(k) as f64) * opt;
        assert!(run.value >= bound - 1e-9, "{} < {}", run.value, bound);
    }

    #[test]
    fn single_machine_equals_centralized() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(120, 8), 45));
        let p = FacilityProblem::new(&ds);
        let central = centralized(&p, 6, "lazy", 9);
        let run = Greedi::new(GreediConfig::new(1, 6)).run(&p, 9);
        assert!((run.value - central.value).abs() < 1e-9);
    }

    #[test]
    fn communication_bounded_by_m_kappa() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 46));
        let p = FacilityProblem::new(&ds);
        let cfg = GreediConfig::new(8, 5).alpha(2.0);
        let kappa = cfg.kappa;
        let run = Greedi::new(cfg).run(&p, 11);
        assert!(run.job.shuffled_elements <= 8 * kappa);
    }
}
