//! The GreeDi protocol — Algorithm 2 (cardinality) and Algorithm 3 (general
//! hereditary constraints) of the paper, executed over the simulated
//! MapReduce runtime.
//!
//! Round 1 (map): partition V over m machines; each runs the configured
//! black-box algorithm (lazy greedy by default) on its shard with budget κ
//! (= α·k, the paper's over-selection knob) or constraint ζ.
//!
//! Round 2 (reduce): merge the m candidate sets into B (≤ m·κ elements —
//! the only communication), run the black box again with budget k, and
//! return the better of { best round-1 set, round-2 set }. With
//! `RunSpec::fanout` set to r < m the merge runs as an r-ary accumulation
//! tree ([`mapreduce::reduce::TreeReduce`](crate::mapreduce::reduce)) whose
//! interior nodes pre-merge under the round-1 constraint, capping any
//! node's pool at r·κ candidates; the default is the flat single-root
//! merge above, bit for bit.
//!
//! In **local mode** (paper §4.5, decomposable objectives) round 1 evaluates
//! the objective restricted to each machine's shard and round 2 on a random
//! ⌈n/m⌉-element window; reported values are always re-evaluated under the
//! true global objective.
//!
//! All parameters come from the shared [`RunSpec`]; `Greedi` itself is a
//! stateless unit struct registered as `"greedi"` in `protocol::by_name`.

use super::metrics::{FaultStats, RunMetrics};
use super::protocol::{Protocol, RunSpec};
use super::Problem;
use crate::algorithms;
use crate::constraints::cardinality::Cardinality;
use crate::constraints::Constraint;
use crate::mapreduce::fault::{FaultPlan, RecoveryPolicy};
use crate::mapreduce::reduce::{NodeOutput, TreeReduce};
use crate::mapreduce::{JobReport, MapReduce};
use crate::util::rng::Rng;
use crate::util::trace;

pub use crate::mapreduce::partition::PartitionStrategy;

/// The two-round distributed maximizer.
pub struct Greedi;

impl Protocol for Greedi {
    /// Algorithm 2: cardinality constraints (κ per machine, k final), or the
    /// spec's explicit per-round constraints when set (Algorithm 3).
    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics {
        let c1;
        let round1: &dyn Constraint = match &spec.round1 {
            Some(c) => c.as_ref(),
            None => {
                c1 = Cardinality::new(spec.kappa);
                &c1
            }
        };
        let c2;
        let round2: &dyn Constraint = match &spec.round2 {
            Some(c) => c.as_ref(),
            None => {
                c2 = Cardinality::new(spec.k);
                &c2
            }
        };
        self.run_constrained(problem, round1, round2, spec)
    }

    fn name(&self) -> &'static str {
        "greedi"
    }
}

impl Greedi {
    /// Algorithm 3: arbitrary hereditary constraints per round. For the
    /// general setting pass the same ζ for both rounds.
    pub fn run_constrained(
        &self,
        problem: &dyn Problem,
        round1: &dyn Constraint,
        round2: &dyn Constraint,
        spec: &RunSpec,
    ) -> RunMetrics {
        let _proto_span = trace::span_with("protocol.greedi", || {
            vec![("m", spec.m.into()), ("k", spec.k.into()), ("threads", spec.threads.into())]
        });
        let base_rng = Rng::new(spec.seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let plan = spec.fault.clone().unwrap_or_else(FaultPlan::none);
        let policy = spec.recovery;
        let multiplicity = spec.multiplicity.clamp(1, spec.m);
        let shards = spec.partition.split_placed(
            &ground,
            spec.m,
            multiplicity,
            spec.placement,
            &plan.domains,
            &mut rng,
        );

        let engine = MapReduce::new(spec.threads);
        let mut job = JobReport::default();

        // ---- Round 1: per-machine black box ------------------------------
        let local_eval = spec.local_eval;
        let algo_name = spec.algorithm.clone();
        let inputs: Vec<(usize, Vec<usize>)> = shards.iter().cloned().enumerate().collect();
        // Leftover pool threads feed each machine's gain engine (map-stage
        // workers × oracle threads never exceeds spec.threads).
        let oracle_threads = spec.oracle_threads(inputs.len());
        // One task body for round 1 AND crash recovery: recovery re-runs a
        // machine with the SAME fork (1000 + i), so a shard rebuilt in full
        // from survivor replicas reproduces the fault-free result bit for
        // bit.
        let run_machine = |i: usize, shard: Vec<usize>| {
            let mut task_rng = base_rng.fork(1000 + i as u64);
            let algo = algorithms::by_name(&algo_name).expect("algorithm");
            let obj = if local_eval {
                problem.local(&shard, &mut task_rng)
            } else {
                problem.global()
            };
            algo.maximize_threaded(obj.as_ref(), &shard, round1, &mut task_rng, oracle_threads)
        };
        let round1_span = trace::span_with("greedi.round1", || vec![("machines", spec.m.into())]);
        let stage1 = engine
            .run_stage_policied(inputs, &plan, policy, |_, (i, shard)| run_machine(i, shard))
            .unwrap_or_else(|e| {
                panic!(
                    "greedi round 1 aborted: {e} (policy=retry turns machine crashes into \
                     job aborts; use drop_shard or survivor_merge to recover)"
                )
            });
        let mut round1_results = stage1.outputs;
        let crashed = stage1.crashed;
        let straggled = stage1.straggled;
        let mut fault_retries = stage1.retries;
        job.stages.push(stage1.report);
        drop(round1_span);

        // ---- Crash recovery ----------------------------------------------
        let mut recovery_time = 0.0;
        let mut dropped = 0usize;
        let mut salvaged_units = 0usize;
        let mut replayed_units = 0usize;
        if !crashed.is_empty() {
            let _rec_span =
                trace::span_with("greedi.recovery", || vec![("crashed", crashed.len().into())]);
            // Elements still held by some surviving machine.
            let surviving: std::collections::HashSet<usize> = shards
                .iter()
                .enumerate()
                .filter(|(i, _)| !crashed.contains(i))
                .flat_map(|(_, s)| s.iter().copied())
                .collect();
            dropped = ground.iter().filter(|e| !surviving.contains(e)).count();
            if policy.rebuilds() {
                // Rebuild each crashed shard from replicas, preserving the
                // original within-shard order, and re-run its map task. When
                // every element survives somewhere (multiplicity ≥ 2, few
                // crashes) the rebuilt shard IS the lost shard, so the
                // recovered candidate set equals the fault-free one exactly.
                // A shard that lost elements (every replica crashed) degrades
                // to drop-shard semantics for the missing part: the partial
                // rebuild runs, coverage() stays < 1.
                let rebuilt: Vec<(usize, Vec<usize>, bool)> = crashed
                    .iter()
                    .map(|&j| {
                        let shard: Vec<usize> =
                            shards[j].iter().copied().filter(|e| surviving.contains(e)).collect();
                        let complete = shard.len() == shards[j].len();
                        (j, shard, complete)
                    })
                    .filter(|(_, shard, _)| !shard.is_empty())
                    .collect();
                if !rebuilt.is_empty() {
                    let rebuilt_ids: Vec<usize> = rebuilt.iter().map(|(j, _, _)| *j).collect();
                    // Resume salvages the crashed machine's last prefix
                    // checkpoint instead of recomputing from scratch — only
                    // when the rebuilt shard is byte-for-byte the lost one
                    // (a checkpoint taken over elements that no longer exist
                    // cannot be replayed) and the black box is the
                    // memoryless greedy family, whose selection is a pure
                    // function of (selected, remaining).
                    let ckpt_b = spec.checkpoint_every;
                    let can_salvage = policy == RecoveryPolicy::Resume
                        && ckpt_b > 0
                        && matches!(algo_name.as_str(), "greedy" | "lazy");
                    let kappa = spec.kappa;
                    let (recovered, rec_stage) =
                        engine.run_stage(rebuilt, |_, (j, shard, complete)| {
                            if can_salvage && complete {
                                // Progress at crash: the SALVAGE coin (or the
                                // plan's pinned fraction) positions the crash
                                // within the machine's planned picks; the
                                // durable checkpoint is the last multiple of
                                // B at or before it.
                                let planned = kappa.min(shard.len());
                                let frac = plan.crash_point(j);
                                let ckpt_picks =
                                    ((frac * planned as f64).floor() as usize / ckpt_b) * ckpt_b;
                                let mut task_rng = base_rng.fork(1000 + j as u64);
                                let obj = if local_eval {
                                    problem.local(&shard, &mut task_rng)
                                } else {
                                    problem.global()
                                };
                                let r = algorithms::greedy::greedy_resumed(
                                    obj.as_ref(),
                                    &shard,
                                    round1,
                                    oracle_threads,
                                    ckpt_picks,
                                );
                                (r.result, r.salvaged_picks, r.replayed_picks)
                            } else {
                                (run_machine(j, shard), 0, 0)
                            }
                        });
                    recovery_time = rec_stage.max_task_time;
                    job.stages.push(rec_stage);
                    for (j, (r, salvaged, replayed)) in rebuilt_ids.into_iter().zip(recovered) {
                        salvaged_units += salvaged;
                        replayed_units += replayed;
                        round1_results[j] = Some(r);
                    }
                }
            }
        }

        let mut oracle_calls: u64 =
            round1_results.iter().flatten().map(|r| r.oracle_calls).sum();

        // ---- Round 2+: accumulation-tree merge ---------------------------
        // Surviving round-1 candidate sets feed the r-ary reduction tree in
        // machine order. The default (flat) fan-in is one root node pooling
        // all m sets — Algorithm 2's single merge machine, bit for bit; an
        // explicit fanout r < m staggers the merge over ⌈log_r m⌉ levels so
        // no node ever pools more than r·κ candidates. Interior nodes merge
        // under the round-1 constraint (κ-budget partial merges, like
        // multiround's levels); the root re-selects under the round-2
        // constraint exactly as before. Crashes model the loss of
        // data-holding map machines — reduce nodes read candidate sets held
        // at the driver, so the root runs under the transient plan only and
        // crashed interior nodes are re-run inline by the tree.
        let candidates: Vec<Vec<usize>> =
            round1_results.iter().flatten().map(|r| r.solution.clone()).collect();
        let total_candidates: usize = candidates.iter().map(|c| c.len()).sum();
        let algo_name2 = spec.algorithm.clone();
        let m = spec.m;
        let tree = TreeReduce::new(spec.tree_fanout(true)).force_root(true);
        let _merge_span =
            trace::span_with("greedi.merge", || vec![("candidates", total_candidates.into())]);
        let tree_run = tree
            .run(&engine, candidates, &plan, policy, &mut job, |ctx, sets| {
                // Per-node RNG: the root keeps the historical merge fork so
                // flat runs reproduce today's outputs; interior nodes fork
                // from (level, node).
                let mut task_rng = if ctx.is_root {
                    base_rng.fork(2000)
                } else {
                    base_rng.fork(900_000 + (ctx.level as u64) * 4096 + ctx.node as u64)
                };
                let con: &dyn Constraint = if ctx.is_root { round2 } else { round1 };
                let node_threads = spec.oracle_threads(ctx.level_nodes);
                let mut pool: Vec<usize> = sets.iter().flatten().copied().collect();
                pool.sort_unstable();
                pool.dedup();
                let obj = if local_eval {
                    problem.merge(m, &mut task_rng)
                } else {
                    problem.global()
                };
                let algo = algorithms::by_name(&algo_name2).expect("algorithm");
                let run_b =
                    algo.maximize_threaded(obj.as_ref(), &pool, con, &mut task_rng, node_threads);
                let mut extra_oracle = run_b.oracle_calls;

                // A^gc_max: the best input set under this node's objective F,
                // trimmed to feasibility under the node constraint if κ > k
                // (prefix-feasible by heredity: keep the greedy selection
                // order).
                let mut best: Option<(Vec<usize>, f64)> = None;
                for cand in sets {
                    let mut trimmed: Vec<usize> = Vec::new();
                    for &e in cand {
                        if con.can_add(&trimmed, e) {
                            trimmed.push(e);
                        }
                    }
                    let v = obj.eval(&trimmed);
                    extra_oracle += trimmed.len() as u64;
                    if best.as_ref().map(|(_, bv)| v > *bv).unwrap_or(true) {
                        best = Some((trimmed, v));
                    }
                }
                let (max_sol, max_val) = best.unwrap_or((Vec::new(), f64::NEG_INFINITY));
                let winner = if run_b.value >= max_val { run_b.solution } else { max_sol };
                let pooled = pool.len();
                NodeOutput { result: winner, pooled, oracle_calls: extra_oracle }
            })
            .unwrap_or_else(|e| panic!("greedi merge aborted: {e}"));
        fault_retries += tree_run.stats.retries;
        oracle_calls += tree_run.oracle_calls;
        let rounds = 1 + tree_run.stats.depth;
        let solution = tree_run.result.unwrap_or_default();
        let tree_stats = tree_run.stats;
        drop(_merge_span);

        // Final reported value: always the true global objective.
        let value = problem.global().eval(&solution);

        let fault = plan.active().then(|| FaultStats {
            policy: policy.label().to_string(),
            multiplicity,
            retries: fault_retries,
            crashed_machines: crashed,
            straggled_machines: straggled,
            dropped_elements: dropped,
            ground_size: ground.len(),
            recovery_time,
            salvaged_units,
            replayed_units,
        });

        RunMetrics {
            name: format!(
                "greedi[m={},k={},κ={}{}{}]",
                spec.m,
                spec.k,
                spec.kappa,
                if multiplicity > 1 {
                    format!(",c={multiplicity}")
                } else {
                    String::new()
                },
                if spec.local_eval { ",local" } else { "" }
            ),
            solution,
            value,
            oracle_calls,
            job,
            rounds,
            stream: None,
            tree: Some(tree_stats),
            fault,
        }
    }
}

/// Centralized reference run (one machine, full ground set, budget k) —
/// the denominator of every ratio the paper reports. Also exposed through
/// the registry as the `"centralized"` protocol. Serial oracle; see
/// [`centralized_threaded`] when thread budget should reach the gain engine.
pub fn centralized(
    problem: &dyn Problem,
    k: usize,
    algorithm: &str,
    seed: u64,
) -> RunMetrics {
    centralized_threaded(problem, k, algorithm, seed, 1)
}

/// [`centralized`] with `threads` OS threads handed to the oracle layer
/// (`State::par_batch_gains`). The single "machine" has the whole host to
/// itself, so unlike the distributed map stages there is nothing to split
/// the budget with. Results are bit-identical at any thread count.
pub fn centralized_threaded(
    problem: &dyn Problem,
    k: usize,
    algorithm: &str,
    seed: u64,
    threads: usize,
) -> RunMetrics {
    let _proto_span = trace::span_with("protocol.centralized", || {
        vec![("k", k.into()), ("threads", threads.into())]
    });
    let engine = MapReduce::new(1);
    let mut job = JobReport::default();
    let ground = problem.ground();
    let base_rng = Rng::new(seed);
    let (mut out, stage) = engine.run_stage(vec![ground], |_, g| {
        let mut rng = base_rng.fork(1);
        let algo = algorithms::by_name(algorithm).expect("algorithm");
        let obj = problem.global();
        algo.maximize_threaded(obj.as_ref(), &g, &Cardinality::new(k), &mut rng, threads)
    });
    job.stages.push(stage);
    let r = out.pop().unwrap();
    RunMetrics {
        name: format!("centralized[k={k}]"),
        value: problem.global().eval(&r.solution),
        solution: r.solution,
        oracle_calls: r.oracle_calls,
        job,
        rounds: 1,
        stream: None,
        tree: None,
        fault: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CutProblem, FacilityProblem, InfoGainProblem, OpaqueProblem};
    use crate::data::graph::social_network;
    use crate::data::synth::{gaussian_blobs, parkinsons_like, SynthConfig};
    use crate::objective::entropy_worstcase::EntropyWorstCase;
    use std::sync::Arc;

    #[test]
    fn greedi_close_to_centralized_on_facility() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 41));
        let p = FacilityProblem::new(&ds);
        let central = centralized(&p, 10, "lazy", 7);
        let run = Greedi.run(&p, &RunSpec::new(5, 10).seed(7));
        assert!(run.solution.len() <= 10);
        let ratio = run.ratio_vs(central.value);
        assert!(ratio > 0.9, "ratio {ratio}");
        assert!(ratio <= 1.0 + 1e-9);
        assert_eq!(run.rounds, 2);
    }

    #[test]
    fn greedi_local_mode_still_competitive() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 42));
        let p = FacilityProblem::new(&ds);
        let central = centralized(&p, 10, "lazy", 3);
        let run = Greedi.run(&p, &RunSpec::new(5, 10).local().seed(3));
        let ratio = run.ratio_vs(central.value);
        assert!(ratio > 0.8, "local ratio {ratio}");
    }

    #[test]
    fn kappa_over_selection_helps_or_equals() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 43));
        let p = FacilityProblem::new(&ds);
        let base = Greedi.run(&p, &RunSpec::new(4, 8).seed(5));
        let over = Greedi.run(&p, &RunSpec::new(4, 8).alpha(2.0).seed(5));
        assert!(over.solution.len() <= 8);
        assert!(over.value >= base.value * 0.98, "{} vs {}", over.value, base.value);
    }

    #[test]
    fn infogain_greedi_ratio() {
        let ds = Arc::new(parkinsons_like(150, 10, 44));
        let p = InfoGainProblem::paper_params(&ds);
        let central = centralized(&p, 8, "lazy", 2);
        let run = Greedi.run(&p, &RunSpec::new(5, 8).seed(2));
        assert!(run.ratio_vs(central.value) > 0.9);
    }

    #[test]
    fn nonmonotone_cut_via_random_greedy() {
        let g = Arc::new(social_network(120, 800, 4));
        let p = CutProblem::new(&g);
        let run = Greedi.run(
            &p,
            &RunSpec::new(4, 10).algorithm("random_greedy").local().seed(6),
        );
        assert!(run.value >= 0.0);
        assert!(run.solution.len() <= 10);
    }

    #[test]
    fn worst_case_instance_respects_theorem3_bound() {
        // On the adversarial instance with contiguous partitioning the
        // distributed value can degrade but never below OPT/min(m,k)
        // multiplied by the greedy factor — and never above OPT.
        let (m, k) = (4, 4);
        let f = EntropyWorstCase::new(m, k);
        let p = OpaqueProblem::new(&f);
        let opt = f.optimal_value(k);
        let run = Greedi.run(
            &p,
            &RunSpec::new(m, k)
                .partition(PartitionStrategy::Contiguous)
                .seed(1),
        );
        assert!(run.value <= opt + 1e-9);
        let bound = (1.0 - (-1.0f64).exp()) / (m.min(k) as f64) * opt;
        assert!(run.value >= bound - 1e-9, "{} < {}", run.value, bound);
    }

    #[test]
    fn single_machine_equals_centralized() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(120, 8), 45));
        let p = FacilityProblem::new(&ds);
        let central = centralized(&p, 6, "lazy", 9);
        let run = Greedi.run(&p, &RunSpec::new(1, 6).seed(9));
        assert!((run.value - central.value).abs() < 1e-9);
    }

    #[test]
    fn multiplicity_replication_runs_and_stays_competitive() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 47));
        let p = FacilityProblem::new(&ds);
        let run = Greedi.run(&p, &RunSpec::new(4, 8).multiplicity(2).seed(5));
        assert!(run.name.contains("c=2"), "{}", run.name);
        assert!(run.solution.len() <= 8);
        assert_eq!(run.job.stages.len(), 2, "no crashes => no recovery stage");
        let base = Greedi.run(&p, &RunSpec::new(4, 8).seed(5));
        assert!(
            run.value >= base.value * 0.9,
            "replication should not tank quality: {} vs {}",
            run.value,
            base.value
        );
    }

    #[test]
    fn resume_recovery_bit_identical_and_salvages_checkpointed_picks() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 48));
        let p = FacilityProblem::new(&ds);
        // Clean reference: same domains (placement input), no faults active.
        let domains = FaultPlan::none().domain_groups(2);
        let spec = |plan: FaultPlan| {
            RunSpec::new(4, 8)
                .multiplicity(2)
                .placement(crate::mapreduce::partition::PlacementPolicy::DistinctDomains)
                .algorithm("greedy")
                .seed(5)
                .faults(plan)
        };
        let clean = Greedi.run(&p, &spec(domains.clone()));
        assert!(clean.fault.is_none(), "bare domain map must not activate the plan");
        // Crash machine 1 at 70% progress; its replicas live in the other
        // domain, so the rebuilt shard is complete and Resume replays only
        // the picks past the last checkpoint.
        let crash = domains.crash_tasks(vec![1]).crash_progress(0.7);
        let run = Greedi.run(
            &p,
            &spec(crash).recovery(RecoveryPolicy::Resume).checkpoint_every(2),
        );
        assert_eq!(run.solution, clean.solution, "resume changed the solution");
        assert_eq!(run.value.to_bits(), clean.value.to_bits());
        let f = run.fault.expect("active plan records stats");
        assert_eq!(f.policy, "resume");
        assert!((f.coverage() - 1.0).abs() < 1e-12, "distinct domains keep coverage 1");
        assert!(f.salvaged_units > 0, "checkpoint at 70% of 8 picks must salvage");
        assert!(f.recompute_saved() > 0.0);
        // Without checkpoints Resume still recovers bit-identically, just
        // with zero salvage (full recompute).
        let cold = Greedi.run(
            &p,
            &spec(FaultPlan::none().domain_groups(2).crash_tasks(vec![1]))
                .recovery(RecoveryPolicy::Resume),
        );
        assert_eq!(cold.solution, clean.solution);
        assert_eq!(cold.fault.unwrap().salvaged_units, 0);
    }

    #[test]
    fn tree_merge_competitive_and_caps_root_pool() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 49));
        let p = FacilityProblem::new(&ds);
        let flat = Greedi.run(&p, &RunSpec::new(8, 6).seed(13));
        let flat_tree = flat.tree.as_ref().expect("greedi reports tree stats");
        assert_eq!(flat_tree.depth, 1, "default = flat single-root merge");
        assert_eq!(flat.rounds, 2);
        let deep = Greedi.run(&p, &RunSpec::new(8, 6).fanout(2).seed(13));
        let deep_tree = deep.tree.as_ref().expect("tree stats");
        assert!(deep_tree.depth > 1, "r=2 over 8 machines must stage the merge");
        assert_eq!(deep.rounds, 1 + deep_tree.depth);
        // interior winners are subsets of their pools, so the staged root
        // can never pool more than the flat root
        assert!(
            deep_tree.root_peak() <= flat_tree.root_peak(),
            "root peak grew: {} vs flat {}",
            deep_tree.root_peak(),
            flat_tree.root_peak()
        );
        assert!(
            deep.value >= 0.9 * flat.value,
            "staged merge lost too much: {} vs {}",
            deep.value,
            flat.value
        );
        // and the staged run stays deterministic
        let again = Greedi.run(&p, &RunSpec::new(8, 6).fanout(2).seed(13));
        assert_eq!(again.solution, deep.solution);
        assert_eq!(again.value.to_bits(), deep.value.to_bits());
    }

    #[test]
    fn communication_bounded_by_m_kappa() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 46));
        let p = FacilityProblem::new(&ds);
        let spec = RunSpec::new(8, 5).alpha(2.0).seed(11);
        let kappa = spec.kappa;
        let run = Greedi.run(&p, &spec);
        assert!(run.job.shuffled_elements <= 8 * kappa);
    }
}
