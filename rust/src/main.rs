//! `greedi` — the leader binary: runs the paper's experiments, the
//! quickstart demo, and utility subcommands over the compiled library.
//!
//! ```text
//! greedi <subcommand> [options]
//!
//! subcommands:
//!   quickstart            tiny end-to-end demo (any registered protocol)
//!   protocols             sweep every registered protocol on one workload
//!   fig4 … fig10          regenerate a figure from the paper's §6
//!   theory                empirical checks of Theorems 3/4/11 + Table 1
//!   fanin                 accumulation-tree fan-in sweep (quality vs root peak)
//!   streaming             bounded-memory sieve→merge vs GreeDi (stream_greedi)
//!   fault_tolerance       quality vs machine crash rate × multiplicity × policy
//!   serve                 always-on selection daemon (see `serve` module)
//!   query                 one wire request against a running daemon
//!   all                   every figure + theory, in order
//!   info                  artifact / build information
//!
//! common options:
//!   --n <int>          ground-set size override
//!   --trials <int>     repetitions per sweep point (default 3)
//!   --seed <int>       base RNG seed (default 42)
//!   --threads <int>    OS threads for the simulated cluster (default 1)
//!   --partition <s>    random | balanced | contiguous (default random)
//!   --multiplicity <c> replicate every element on c machines (default 1)
//!   --placement <s>    anywhere | distinct_domains (default anywhere)
//!   --recovery <s>     retry | drop_shard | survivor_merge | resume (default retry)
//!   --checkpoint-every <b>  snapshot partial progress every b units under
//!                      --recovery resume (default 0 = off)
//!   --protocol <name>  protocol for `quickstart` (see `protocol::by_name`;
//!                      default greedi — figure harnesses run their fixed suites)
//!   --part <a|b|c|d>   figure sub-part filter
//!   --xla              use the AOT/PJRT gain oracle where applicable
//!   --full             lift sizes toward paper scale
//!   --config <path>    load an ExperimentConfig preset (configs/*.toml)
//!   --trace <path>     write a Chrome trace + NDJSON sidecar (util::trace;
//!                      also GREEDI_TRACE env or the `trace` config key)
//!
//! serve options:
//!   --addr <h:p>       listen address (also `[serve] addr`; default 127.0.0.1:7199)
//!   --concurrency <c>  max queries in flight   --queue <q>  bounded wait depth
//!   --stream           register the demo dataset as a drifting stream
//!
//! query options:
//!   --addr <h:p>       daemon address
//!   --op <name>        query | ping | stats | datasets | warm | advance | shutdown
//!   --m/--k/--dataset  query shape (spec fields also honor common options)
//! ```

use greedi::config::ExperimentConfig;
use greedi::coordinator::protocol::{
    self, PartitionStrategy, PlacementPolicy, Protocol, RecoveryPolicy, RunSpec,
};
use greedi::experiments::{self, ExpOpts, FigureReport};
use greedi::util::args::Args;
use greedi::util::trace;

fn opts_from(args: &Args) -> ExpOpts {
    ExpOpts {
        n: args.get("n").map(|v| v.parse().expect("--n expects an integer")),
        trials: args.get_usize("trials", 3),
        seed: args.get_u64("seed", 42),
        threads: args.get_usize("threads", 1),
        partition: args
            .get("partition")
            .map(|s| {
                PartitionStrategy::parse(s).unwrap_or_else(|| {
                    panic!("--partition expects random|balanced|contiguous, got {s:?}")
                })
            })
            .unwrap_or(PartitionStrategy::Random),
        multiplicity: args.get_usize("multiplicity", 1),
        placement: args
            .get("placement")
            .map(|s| {
                PlacementPolicy::parse(s).unwrap_or_else(|| {
                    panic!("--placement expects anywhere|distinct_domains, got {s:?}")
                })
            })
            .unwrap_or(PlacementPolicy::Anywhere),
        recovery: args
            .get("recovery")
            .map(|s| {
                RecoveryPolicy::parse(s).unwrap_or_else(|| {
                    panic!(
                        "--recovery expects retry|drop_shard|survivor_merge|resume, got {s:?}"
                    )
                })
            })
            .unwrap_or(RecoveryPolicy::Retry),
        checkpoint_every: args.get_usize("checkpoint-every", 0),
        xla: args.has_flag("xla"),
        full: args.has_flag("full"),
        part: args.get_str("part", ""),
    }
}

fn run_figure(name: &str, opts: &ExpOpts) -> Option<FigureReport> {
    Some(match name {
        "fig4" => experiments::fig4::run(opts),
        "fig5" => experiments::fig5::run(opts),
        "fig6" => experiments::fig6::run(opts),
        "fig7" => experiments::fig7::run(opts),
        "fig8" => experiments::fig8::run(opts),
        "fig9" => experiments::fig9::run(opts),
        "fig10" => experiments::fig10::run(opts),
        "theory" => experiments::theory::run(opts),
        "ablations" => experiments::ablations::run(opts),
        "fanin" => experiments::fanin::run(opts),
        "streaming" => experiments::streaming::run(opts),
        "fault_tolerance" => experiments::fault_tolerance::run(opts),
        _ => return None,
    })
}

fn demo_problem(opts: &ExpOpts, n: usize) -> greedi::coordinator::FacilityProblem {
    use greedi::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), opts.seed));
    greedi::coordinator::FacilityProblem::new(&ds)
}

/// Shared spec for the demo subcommands: preset keys (algorithm,
/// local_eval, …) come from the config when one is loaded; CLI-merged
/// options (seed/threads/partition) always win.
fn base_spec(opts: &ExpOpts, cfg: Option<&ExperimentConfig>, m: usize, k: usize) -> RunSpec {
    let mut spec = match cfg {
        Some(c) => c.run_spec(m, k),
        None => RunSpec::new(m, k),
    };
    spec.partition = opts.partition;
    spec.threads = opts.threads;
    spec.seed = opts.seed;
    spec
}

fn quickstart(opts: &ExpOpts, cfg: Option<&ExperimentConfig>, proto_name: &str) {
    let Some(proto) = protocol::by_name(proto_name) else {
        eprintln!(
            "unknown protocol {proto_name:?} — known: {}",
            protocol::NAMES.join(", ")
        );
        std::process::exit(2);
    };
    let n = opts.n.unwrap_or(1_000);
    println!(
        "GreeDi quickstart: exemplar clustering, n={n}, d=16, m=5, k=10, protocol={proto_name}\n"
    );
    let problem = demo_problem(opts, n);
    let spec = base_spec(opts, cfg, 5, 10);
    let central = protocol::by_name("centralized").unwrap().run(&problem, &spec);
    println!("  {}", central.one_line());
    let run = proto.run(&problem, &spec);
    println!("  {}", run.one_line());
    println!(
        "\n  distributed/centralized ratio = {:.4} (paper: ≈0.98 for exemplar clustering with greedi)",
        run.ratio_vs(central.value)
    );
}

/// Sweep the whole protocol registry on one workload under one shared spec —
/// the unified-API showcase.
fn protocols(opts: &ExpOpts, cfg: Option<&ExperimentConfig>) {
    let n = opts.n.unwrap_or(1_000);
    let (m, k) = (5, 10);
    println!(
        "protocol sweep: exemplar clustering, n={n}, m={m}, k={k}, threads={}\n",
        opts.threads
    );
    let problem = demo_problem(opts, n);
    let spec = base_spec(opts, cfg, m, k);
    let central = protocol::by_name("centralized").unwrap().run(&problem, &spec);
    for name in protocol::NAMES {
        let run = protocol::by_name(name).unwrap().run(&problem, &spec);
        println!(
            "  {name:<16} ratio={:.4}  {}",
            run.ratio_vs(central.value),
            run.one_line()
        );
    }
}

/// `greedi serve`: boot the always-on selection daemon and park until a
/// client sends the wire `shutdown` op.
fn serve_cmd(args: &Args, opts: &ExpOpts) {
    use greedi::data::synth::{gaussian_blobs, SynthConfig};
    use greedi::serve::{ServeSpec, Server, WarmState};
    use greedi::stream::{DriftSource, StreamOrder};
    use std::sync::Arc;

    let mut spec = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                eprintln!("config error: {path}: {e}");
                std::process::exit(2);
            });
            ServeSpec::from_toml(&text).unwrap_or_else(|e| {
                eprintln!("config error: {path}: {e}");
                std::process::exit(2);
            })
        }
        None => ServeSpec::default(),
    };
    // CLI overrides win over the [serve] section, same as everywhere else
    if let Some(addr) = args.get("addr") {
        spec.addr = addr.to_string();
    }
    if args.get("threads").is_some() {
        spec.threads = opts.threads;
    }
    spec.max_concurrency = args.get_usize("concurrency", spec.max_concurrency);
    spec.queue_depth = args.get_usize("queue", spec.queue_depth);
    if let Some(name) = args.get("dataset") {
        spec.dataset = name.to_string();
    }
    spec.validate().unwrap_or_else(|e| {
        eprintln!("serve config error: {e}");
        std::process::exit(2);
    });

    let n = opts.n.unwrap_or(2_000);
    let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), opts.seed));
    let state = Arc::new(WarmState::new());
    if args.has_flag("stream") {
        // drifting corpus: half the stream now, `advance` pulls the rest
        let src = DriftSource::new(&data, data.ids(), StreamOrder::Drift);
        let live = state
            .register_streaming(&spec.dataset, Arc::clone(&data), Box::new(src), n / 2)
            .unwrap_or_else(|e| {
                eprintln!("serve: {e}");
                std::process::exit(2);
            });
        println!("dataset {:?}: streaming, {live}/{n} points live", spec.dataset);
    } else {
        state.register(&spec.dataset, Arc::clone(&data));
        println!("dataset {:?}: static, {n} points", spec.dataset);
    }

    let mut server = Server::start(&spec, state).unwrap_or_else(|e| {
        eprintln!("serve: {e}");
        std::process::exit(2);
    });
    println!(
        "greedi serve: listening on {} (budget {} threads / {} slots, queue {})",
        server.addr(),
        spec.threads,
        spec.max_concurrency,
        spec.queue_depth
    );
    println!("stop with: greedi query --addr {} --op shutdown", server.addr());
    server.join();
    println!("greedi serve: shutdown received, bye");
}

/// `greedi query`: one wire request against a running daemon.
fn query_cmd(args: &Args, opts: &ExpOpts, cfg: Option<&ExperimentConfig>) {
    use greedi::serve::Client;

    let addr = args.get_str("addr", "127.0.0.1:7199");
    let mut client = Client::connect(addr.as_str()).unwrap_or_else(|e| {
        eprintln!("query: {e} (is `greedi serve` running on {addr}?)");
        std::process::exit(2);
    });
    let dataset = args.get("dataset");
    let op = args.get_str("op", "query");
    let outcome = match op.as_str() {
        "ping" => client.ping(),
        "stats" => client.stats(),
        "datasets" => client.datasets(),
        "warm" => client.warm(dataset),
        "advance" => client.advance(dataset, args.get_usize("count", 100)),
        "shutdown" => client.shutdown(),
        "query" => {
            let m = args.get_usize("m", 5);
            let k = args.get_usize("k", 10);
            let spec = base_spec(opts, cfg, m, k);
            let proto = args.get_str("protocol", "greedi");
            match client.query(&proto, dataset, &spec) {
                Err(e) => Err(e),
                Ok(r) => {
                    println!(
                        "{}: f(S) = {}, |S| = {}, oracle calls = {}, rounds = {}",
                        r.protocol,
                        r.value,
                        r.solution.len(),
                        r.oracle_calls,
                        r.rounds
                    );
                    println!(
                        "dataset {} v{}; {} threads; queued {:.1}us, latency {:.1}us",
                        r.dataset, r.dataset_version, r.threads_used, r.queued_us, r.latency_us
                    );
                    return;
                }
            }
        }
        other => {
            eprintln!("query: unknown --op {other:?} (query|ping|stats|datasets|warm|advance|shutdown)");
            std::process::exit(2);
        }
    };
    match outcome {
        Ok(result) => println!("{}", result.dump()),
        Err(e) => {
            eprintln!("query: {e}");
            std::process::exit(1);
        }
    }
}

fn info() {
    println!("greedi — distributed submodular maximization (Mirzasoleiman et al., 2014)");
    println!("three-layer build: rust coordinator + JAX L2 graphs + Pallas L1 kernels (AOT)");
    println!("registered protocols: {}", protocol::NAMES.join(", "));
    let dir = greedi::runtime::default_artifact_dir();
    match greedi::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for e in &m.entries {
                println!("  {:<34} in={:?} out={:?}  {}", e.name, e.inputs, e.outputs, e.doc);
            }
        }
        Err(e) => println!("artifacts not available: {e}"),
    }
}

fn main() {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().cloned() else {
        eprintln!("usage: greedi <quickstart|protocols|serve|query|fig4..fig10|theory|ablations|fanin|streaming|fault_tolerance|all|info> [--n N] [--trials T] [--seed S] [--threads T] [--partition S] [--multiplicity C] [--placement S] [--recovery P] [--checkpoint-every B] [--protocol P] [--part P] [--xla] [--full]");
        std::process::exit(2);
    };
    let mut opts = opts_from(&args);
    let mut proto_name = args.get_str("protocol", "greedi");
    let mut cfg_opt: Option<ExperimentConfig> = None;
    if let Some(path) = args.get("config") {
        let cfg = ExperimentConfig::from_file(std::path::Path::new(path)).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        });
        // preset values apply only where the CLI didn't say otherwise
        if args.get("n").is_none() {
            opts.n = Some(cfg.n);
        }
        if args.get("trials").is_none() {
            opts.trials = cfg.trials;
        }
        if args.get("seed").is_none() {
            opts.seed = cfg.seed;
        }
        if args.get("threads").is_none() {
            opts.threads = cfg.threads;
        }
        if args.get("partition").is_none() {
            opts.partition = cfg.partition;
        }
        if args.get("multiplicity").is_none() {
            opts.multiplicity = cfg.multiplicity;
        }
        if args.get("placement").is_none() {
            opts.placement = cfg.placement;
        }
        if args.get("recovery").is_none() {
            opts.recovery = cfg.recovery;
        }
        if args.get("checkpoint-every").is_none() {
            opts.checkpoint_every = cfg.checkpoint_every;
        }
        if args.get("protocol").is_none() {
            proto_name = cfg.protocol.clone();
        }
        println!(
            "loaded config preset {:?} (workload {}, protocol {})",
            cfg.name,
            cfg.workload.label(),
            cfg.protocol
        );
        cfg_opt = Some(cfg);
    }

    // Trace activation precedence: --trace > GREEDI_TRACE > config `trace`.
    if let Some(path) = args.get("trace") {
        trace::enable(path);
    } else if trace::init_from_env().is_none() {
        if let Some(path) = cfg_opt.as_ref().and_then(|c| c.trace.as_deref()) {
            trace::enable(path);
        }
    }

    match cmd.as_str() {
        "quickstart" => quickstart(&opts, cfg_opt.as_ref(), &proto_name),
        "protocols" => protocols(&opts, cfg_opt.as_ref()),
        "serve" => serve_cmd(&args, &opts),
        "query" => query_cmd(&args, &opts, cfg_opt.as_ref()),
        "info" => info(),
        "all" => {
            for f in ["fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "theory", "ablations", "fanin", "streaming", "fault_tolerance"] {
                run_figure(f, &opts).unwrap().print();
            }
        }
        other => match run_figure(other, &opts) {
            Some(rep) => rep.print(),
            None => {
                eprintln!("unknown subcommand {other:?}");
                std::process::exit(2);
            }
        },
    }

    if let Some(path) = trace::flush() {
        eprintln!("trace written to {} (+ NDJSON sidecar)", path.display());
    }
}
