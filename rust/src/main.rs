//! `greedi` — the leader binary: runs the paper's experiments, the
//! quickstart demo, and utility subcommands over the compiled library.
//!
//! ```text
//! greedi <subcommand> [options]
//!
//! subcommands:
//!   quickstart            tiny end-to-end demo (any registered protocol)
//!   protocols             sweep every registered protocol on one workload
//!   fig4 … fig10          regenerate a figure from the paper's §6
//!   theory                empirical checks of Theorems 3/4/11 + Table 1
//!   streaming             bounded-memory sieve→merge vs GreeDi (stream_greedi)
//!   all                   every figure + theory, in order
//!   info                  artifact / build information
//!
//! common options:
//!   --n <int>          ground-set size override
//!   --trials <int>     repetitions per sweep point (default 3)
//!   --seed <int>       base RNG seed (default 42)
//!   --threads <int>    OS threads for the simulated cluster (default 1)
//!   --partition <s>    random | balanced | contiguous (default random)
//!   --protocol <name>  protocol for `quickstart` (see `protocol::by_name`;
//!                      default greedi — figure harnesses run their fixed suites)
//!   --part <a|b|c|d>   figure sub-part filter
//!   --xla              use the AOT/PJRT gain oracle where applicable
//!   --full             lift sizes toward paper scale
//!   --config <path>    load an ExperimentConfig preset (configs/*.toml)
//! ```

use greedi::config::ExperimentConfig;
use greedi::coordinator::protocol::{self, PartitionStrategy, Protocol, RunSpec};
use greedi::experiments::{self, ExpOpts, FigureReport};
use greedi::util::args::Args;

fn opts_from(args: &Args) -> ExpOpts {
    ExpOpts {
        n: args.get("n").map(|v| v.parse().expect("--n expects an integer")),
        trials: args.get_usize("trials", 3),
        seed: args.get_u64("seed", 42),
        threads: args.get_usize("threads", 1),
        partition: args
            .get("partition")
            .map(|s| {
                PartitionStrategy::parse(s).unwrap_or_else(|| {
                    panic!("--partition expects random|balanced|contiguous, got {s:?}")
                })
            })
            .unwrap_or(PartitionStrategy::Random),
        xla: args.has_flag("xla"),
        full: args.has_flag("full"),
        part: args.get_str("part", ""),
    }
}

fn run_figure(name: &str, opts: &ExpOpts) -> Option<FigureReport> {
    Some(match name {
        "fig4" => experiments::fig4::run(opts),
        "fig5" => experiments::fig5::run(opts),
        "fig6" => experiments::fig6::run(opts),
        "fig7" => experiments::fig7::run(opts),
        "fig8" => experiments::fig8::run(opts),
        "fig9" => experiments::fig9::run(opts),
        "fig10" => experiments::fig10::run(opts),
        "theory" => experiments::theory::run(opts),
        "ablations" => experiments::ablations::run(opts),
        "streaming" => experiments::streaming::run(opts),
        _ => return None,
    })
}

fn demo_problem(opts: &ExpOpts, n: usize) -> greedi::coordinator::FacilityProblem {
    use greedi::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), opts.seed));
    greedi::coordinator::FacilityProblem::new(&ds)
}

/// Shared spec for the demo subcommands: preset keys (algorithm,
/// local_eval, …) come from the config when one is loaded; CLI-merged
/// options (seed/threads/partition) always win.
fn base_spec(opts: &ExpOpts, cfg: Option<&ExperimentConfig>, m: usize, k: usize) -> RunSpec {
    let mut spec = match cfg {
        Some(c) => c.run_spec(m, k),
        None => RunSpec::new(m, k),
    };
    spec.partition = opts.partition;
    spec.threads = opts.threads;
    spec.seed = opts.seed;
    spec
}

fn quickstart(opts: &ExpOpts, cfg: Option<&ExperimentConfig>, proto_name: &str) {
    let Some(proto) = protocol::by_name(proto_name) else {
        eprintln!(
            "unknown protocol {proto_name:?} — known: {}",
            protocol::NAMES.join(", ")
        );
        std::process::exit(2);
    };
    let n = opts.n.unwrap_or(1_000);
    println!(
        "GreeDi quickstart: exemplar clustering, n={n}, d=16, m=5, k=10, protocol={proto_name}\n"
    );
    let problem = demo_problem(opts, n);
    let spec = base_spec(opts, cfg, 5, 10);
    let central = protocol::by_name("centralized").unwrap().run(&problem, &spec);
    println!("  {}", central.one_line());
    let run = proto.run(&problem, &spec);
    println!("  {}", run.one_line());
    println!(
        "\n  distributed/centralized ratio = {:.4} (paper: ≈0.98 for exemplar clustering with greedi)",
        run.ratio_vs(central.value)
    );
}

/// Sweep the whole protocol registry on one workload under one shared spec —
/// the unified-API showcase.
fn protocols(opts: &ExpOpts, cfg: Option<&ExperimentConfig>) {
    let n = opts.n.unwrap_or(1_000);
    let (m, k) = (5, 10);
    println!(
        "protocol sweep: exemplar clustering, n={n}, m={m}, k={k}, threads={}\n",
        opts.threads
    );
    let problem = demo_problem(opts, n);
    let spec = base_spec(opts, cfg, m, k);
    let central = protocol::by_name("centralized").unwrap().run(&problem, &spec);
    for name in protocol::NAMES {
        let run = protocol::by_name(name).unwrap().run(&problem, &spec);
        println!(
            "  {name:<16} ratio={:.4}  {}",
            run.ratio_vs(central.value),
            run.one_line()
        );
    }
}

fn info() {
    println!("greedi — distributed submodular maximization (Mirzasoleiman et al., 2014)");
    println!("three-layer build: rust coordinator + JAX L2 graphs + Pallas L1 kernels (AOT)");
    println!("registered protocols: {}", protocol::NAMES.join(", "));
    let dir = greedi::runtime::default_artifact_dir();
    match greedi::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for e in &m.entries {
                println!("  {:<34} in={:?} out={:?}  {}", e.name, e.inputs, e.outputs, e.doc);
            }
        }
        Err(e) => println!("artifacts not available: {e}"),
    }
}

fn main() {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().cloned() else {
        eprintln!("usage: greedi <quickstart|protocols|fig4..fig10|theory|ablations|streaming|all|info> [--n N] [--trials T] [--seed S] [--threads T] [--partition S] [--protocol P] [--part P] [--xla] [--full]");
        std::process::exit(2);
    };
    let mut opts = opts_from(&args);
    let mut proto_name = args.get_str("protocol", "greedi");
    let mut cfg_opt: Option<ExperimentConfig> = None;
    if let Some(path) = args.get("config") {
        let cfg = ExperimentConfig::from_file(std::path::Path::new(path)).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        });
        // preset values apply only where the CLI didn't say otherwise
        if args.get("n").is_none() {
            opts.n = Some(cfg.n);
        }
        if args.get("trials").is_none() {
            opts.trials = cfg.trials;
        }
        if args.get("seed").is_none() {
            opts.seed = cfg.seed;
        }
        if args.get("threads").is_none() {
            opts.threads = cfg.threads;
        }
        if args.get("partition").is_none() {
            opts.partition = cfg.partition;
        }
        if args.get("protocol").is_none() {
            proto_name = cfg.protocol.clone();
        }
        println!(
            "loaded config preset {:?} (workload {}, protocol {})",
            cfg.name,
            cfg.workload.label(),
            cfg.protocol
        );
        cfg_opt = Some(cfg);
    }

    match cmd.as_str() {
        "quickstart" => quickstart(&opts, cfg_opt.as_ref(), &proto_name),
        "protocols" => protocols(&opts, cfg_opt.as_ref()),
        "info" => info(),
        "all" => {
            for f in ["fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "theory", "ablations", "streaming"] {
                run_figure(f, &opts).unwrap().print();
            }
        }
        other => match run_figure(other, &opts) {
            Some(rep) => rep.print(),
            None => {
                eprintln!("unknown subcommand {other:?}");
                std::process::exit(2);
            }
        },
    }
}
