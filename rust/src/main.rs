//! `greedi` — the leader binary: runs the paper's experiments, the
//! quickstart demo, and utility subcommands over the compiled library.
//!
//! ```text
//! greedi <subcommand> [options]
//!
//! subcommands:
//!   quickstart            tiny end-to-end GreeDi demo
//!   fig4 … fig10          regenerate a figure from the paper's §6
//!   theory                empirical checks of Theorems 3/4/11 + Table 1
//!   all                   every figure + theory, in order
//!   info                  artifact / build information
//!
//! common options:
//!   --n <int>        ground-set size override
//!   --trials <int>   repetitions per sweep point (default 3)
//!   --seed <int>     base RNG seed (default 42)
//!   --part <a|b|c|d> figure sub-part filter
//!   --xla            use the AOT/PJRT gain oracle where applicable
//!   --full           lift sizes toward paper scale
//!   --config <path>  load an ExperimentConfig preset (configs/*.toml)
//! ```

use greedi::experiments::{self, ExpOpts, FigureReport};
use greedi::util::args::Args;

fn opts_from(args: &Args) -> ExpOpts {
    ExpOpts {
        n: args.get("n").map(|v| v.parse().expect("--n expects an integer")),
        trials: args.get_usize("trials", 3),
        seed: args.get_u64("seed", 42),
        xla: args.has_flag("xla"),
        full: args.has_flag("full"),
        part: args.get_str("part", ""),
    }
}

fn run_figure(name: &str, opts: &ExpOpts) -> Option<FigureReport> {
    Some(match name {
        "fig4" => experiments::fig4::run(opts),
        "fig5" => experiments::fig5::run(opts),
        "fig6" => experiments::fig6::run(opts),
        "fig7" => experiments::fig7::run(opts),
        "fig8" => experiments::fig8::run(opts),
        "fig9" => experiments::fig9::run(opts),
        "fig10" => experiments::fig10::run(opts),
        "theory" => experiments::theory::run(opts),
        "ablations" => experiments::ablations::run(opts),
        _ => return None,
    })
}

fn quickstart(opts: &ExpOpts) {
    use greedi::coordinator::greedi::{centralized, Greedi, GreediConfig};
    use greedi::coordinator::FacilityProblem;
    use greedi::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;

    let n = opts.n.unwrap_or(1_000);
    println!("GreeDi quickstart: exemplar clustering, n={n}, d=16, m=5, k=10\n");
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), opts.seed));
    let problem = FacilityProblem::new(&ds);
    let central = centralized(&problem, 10, "lazy", opts.seed);
    println!("  {}", central.one_line());
    let run = Greedi::new(GreediConfig::new(5, 10)).run(&problem, opts.seed);
    println!("  {}", run.one_line());
    println!(
        "\n  distributed/centralized ratio = {:.4} (paper: ≈0.98 for exemplar clustering)",
        run.ratio_vs(central.value)
    );
}

fn info() {
    println!("greedi — distributed submodular maximization (Mirzasoleiman et al., 2014)");
    println!("three-layer build: rust coordinator + JAX L2 graphs + Pallas L1 kernels (AOT)");
    let dir = greedi::runtime::default_artifact_dir();
    match greedi::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for e in &m.entries {
                println!("  {:<34} in={:?} out={:?}  {}", e.name, e.inputs, e.outputs, e.doc);
            }
        }
        Err(e) => println!("artifacts not available: {e}"),
    }
}

fn main() {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().cloned() else {
        eprintln!("usage: greedi <quickstart|fig4..fig10|theory|ablations|all|info> [--n N] [--trials T] [--seed S] [--part P] [--xla] [--full]");
        std::process::exit(2);
    };
    let mut opts = opts_from(&args);
    if let Some(path) = args.get("config") {
        let cfg = greedi::config::ExperimentConfig::from_file(std::path::Path::new(path))
            .unwrap_or_else(|e| {
                eprintln!("config error: {e}");
                std::process::exit(2);
            });
        opts.n = Some(cfg.n);
        opts.trials = cfg.trials;
        opts.seed = cfg.seed;
        println!("loaded config preset {:?} (workload {})", cfg.name, cfg.workload.label());
    }

    match cmd.as_str() {
        "quickstart" => quickstart(&opts),
        "info" => info(),
        "all" => {
            for f in ["fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "theory", "ablations"] {
                run_figure(f, &opts).unwrap().print();
            }
        }
        other => match run_figure(other, &opts) {
            Some(rep) => rep.print(),
            None => {
                eprintln!("unknown subcommand {other:?}");
                std::process::exit(2);
            }
        },
    }
}
