//! Typed experiment configuration, loadable from `configs/*.toml` presets
//! (via the `util::toml` subset parser) and overridable from the CLI.
//!
//! Presets drive the unified protocol API: a `protocol = "..."` key selects
//! any `protocol::by_name` entry, and [`ExperimentConfig::run_spec`] turns a
//! preset plus one (m, k) sweep point into the shared [`RunSpec`].

use std::path::Path;

pub use crate::coordinator::protocol::{
    PartitionStrategy, PlacementPolicy, RecoveryPolicy, RunSpec,
};
use crate::coordinator::protocol;
use crate::util::toml;

/// Which scenario an experiment run drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// Exemplar clustering on tiny-image-like vectors (§6.1).
    TinyImages,
    /// GP active-set selection on Parkinsons-like vectors (§6.2).
    Parkinsons,
    /// GP active-set selection on Yahoo-like 6-d features (§6.2 large).
    Yahoo,
    /// Max-cut on a social graph (§6.3).
    SocialCut,
    /// Coverage on Accidents-like transactions (§6.4).
    Accidents,
    /// Coverage on Kosarak-like transactions (§6.4).
    Kosarak,
}

impl Workload {
    pub fn parse(s: &str) -> Option<Workload> {
        Some(match s {
            "tiny_images" => Workload::TinyImages,
            "parkinsons" => Workload::Parkinsons,
            "yahoo" => Workload::Yahoo,
            "social_cut" => Workload::SocialCut,
            "accidents" => Workload::Accidents,
            "kosarak" => Workload::Kosarak,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Workload::TinyImages => "tiny_images",
            Workload::Parkinsons => "parkinsons",
            Workload::Yahoo => "yahoo",
            Workload::SocialCut => "social_cut",
            Workload::Accidents => "accidents",
            Workload::Kosarak => "kosarak",
        }
    }
}

/// Full experiment description (what one `greedi <figN>` invocation runs).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub workload: Workload,
    /// Distributed protocol to drive (see `protocol::by_name`).
    pub protocol: String,
    /// Ground set size (scaled-down stand-in for the paper's corpus).
    pub n: usize,
    /// Feature dimension (point workloads).
    pub d: usize,
    /// Budgets to sweep.
    pub ks: Vec<usize>,
    /// Machine counts to sweep.
    pub ms: Vec<usize>,
    /// κ/k over-selection factors to sweep (GreeDi curves per α).
    pub alphas: Vec<f64>,
    /// Local (decomposable) evaluation mode.
    pub local_eval: bool,
    /// Per-machine algorithm.
    pub algorithm: String,
    /// Ground-set partitioning strategy.
    pub partition: PartitionStrategy,
    /// Replication multiplicity c ≥ 1 (every element on c distinct machines).
    pub multiplicity: usize,
    /// Where replicas land relative to the fault plan's failure domains
    /// ("anywhere" / "distinct_domains").
    pub placement: PlacementPolicy,
    /// Crash-recovery policy for the map stages.
    pub recovery: RecoveryPolicy,
    /// Checkpoint period B for `recovery = "resume"` (0 = checkpoints off).
    pub checkpoint_every: usize,
    /// OS threads for the simulated cluster.
    pub threads: usize,
    /// Accumulation-tree fan-in r for greedi/multiround/stream_greedi
    /// (`0` = protocol default: flat merge, or a binary tree for
    /// multiround; otherwise ≥ 2).
    pub fanout: usize,
    /// Stream batch size (`protocol = "stream_greedi"`; output-invariant).
    pub batch: usize,
    /// Approximation slack ε ∈ (0, 1): greedy_scaling's threshold decay and
    /// stream_greedi's sieve-ladder resolution.
    pub epsilon: f64,
    /// Repetitions (figures show mean ± std).
    pub trials: usize,
    pub seed: u64,
    /// Structured-trace output path (`util::trace`); `None` leaves tracing
    /// off unless `GREEDI_TRACE` / `--trace` asks for it.
    pub trace: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "custom".into(),
            workload: Workload::TinyImages,
            protocol: "greedi".into(),
            n: 1000,
            d: 8,
            ks: vec![50],
            ms: vec![5],
            alphas: vec![1.0],
            local_eval: false,
            algorithm: "lazy".into(),
            partition: PartitionStrategy::Random,
            multiplicity: 1,
            placement: PlacementPolicy::Anywhere,
            recovery: RecoveryPolicy::Retry,
            checkpoint_every: 0,
            threads: 1,
            fanout: 0,
            batch: 256,
            epsilon: 0.5,
            trials: 3,
            seed: 42,
            trace: None,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file; unknown keys are rejected so presets
    /// cannot silently drift from the schema.
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_toml(&text).map_err(|e| format!("{path:?}: {e}"))
    }

    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = ExperimentConfig::default();
        for (key, value) in &doc.entries {
            match key.as_str() {
                "name" => cfg.name = value.as_str().ok_or("name: string")?.into(),
                "workload" => {
                    let s = value.as_str().ok_or("workload: string")?;
                    cfg.workload =
                        Workload::parse(s).ok_or_else(|| format!("unknown workload {s}"))?;
                }
                "protocol" => {
                    cfg.protocol = value.as_str().ok_or("protocol: string")?.into()
                }
                "n" => cfg.n = value.as_usize().ok_or("n: int")?,
                "d" => cfg.d = value.as_usize().ok_or("d: int")?,
                "ks" => cfg.ks = value.as_usize_array().ok_or("ks: [int]")?,
                "ms" => cfg.ms = value.as_usize_array().ok_or("ms: [int]")?,
                "alphas" => {
                    cfg.alphas = match value {
                        toml::Value::Array(xs) => xs
                            .iter()
                            .map(|v| v.as_f64().ok_or("alphas: [float]"))
                            .collect::<Result<_, _>>()?,
                        _ => return Err("alphas: [float]".into()),
                    }
                }
                "local_eval" => cfg.local_eval = value.as_bool().ok_or("local_eval: bool")?,
                "algorithm" => cfg.algorithm = value.as_str().ok_or("algorithm: string")?.into(),
                "partition" => {
                    let s = value.as_str().ok_or("partition: string")?;
                    cfg.partition = PartitionStrategy::parse(s)
                        .ok_or_else(|| format!("unknown partition strategy {s}"))?;
                }
                "multiplicity" => {
                    cfg.multiplicity = value.as_usize().ok_or("multiplicity: int")?
                }
                "placement" => {
                    let s = value.as_str().ok_or("placement: string")?;
                    cfg.placement = PlacementPolicy::parse(s)
                        .ok_or_else(|| format!("unknown placement policy {s}"))?;
                }
                "recovery" => {
                    let s = value.as_str().ok_or("recovery: string")?;
                    cfg.recovery = RecoveryPolicy::parse(s)
                        .ok_or_else(|| format!("unknown recovery policy {s}"))?;
                }
                "checkpoint_every" => {
                    cfg.checkpoint_every = value.as_usize().ok_or("checkpoint_every: int")?
                }
                "threads" => cfg.threads = value.as_usize().ok_or("threads: int")?,
                "fanout" => cfg.fanout = value.as_usize().ok_or("fanout: int")?,
                "batch" => cfg.batch = value.as_usize().ok_or("batch: int")?,
                "epsilon" => cfg.epsilon = value.as_f64().ok_or("epsilon: float")?,
                "trials" => cfg.trials = value.as_usize().ok_or("trials: int")?,
                "seed" => cfg.seed = value.as_i64().ok_or("seed: int")? as u64,
                "trace" => cfg.trace = Some(value.as_str().ok_or("trace: string")?.into()),
                // the [serve] section belongs to serve::ServeSpec — one
                // preset file can carry both; ServeSpec::from_doc enforces
                // the same unknown-key discipline over its own keys
                key if key.starts_with("serve.") => {}
                other => return Err(format!("unknown config key {other:?}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("n must be > 0".into());
        }
        if self.ks.is_empty() || self.ms.is_empty() {
            return Err("ks and ms must be non-empty".into());
        }
        if self.ks.iter().any(|&k| k == 0) {
            return Err("all ks must be > 0".into());
        }
        if self.ms.iter().any(|&m| m == 0) {
            return Err("all ms must be > 0".into());
        }
        if crate::algorithms::by_name(&self.algorithm).is_none() {
            return Err(format!("unknown algorithm {:?}", self.algorithm));
        }
        if protocol::by_name(&self.protocol).is_none() {
            return Err(format!("unknown protocol {:?}", self.protocol));
        }
        if self.threads == 0 {
            return Err("threads must be > 0".into());
        }
        if self.multiplicity == 0 {
            return Err("multiplicity must be >= 1".into());
        }
        if self.fanout == 1 {
            return Err("fanout must be 0 (protocol default) or >= 2".into());
        }
        if self.batch == 0 {
            return Err("batch must be > 0".into());
        }
        if !(self.epsilon > 0.0 && self.epsilon < 1.0) {
            return Err("epsilon must be in (0, 1)".into());
        }
        if self.trials == 0 {
            return Err("trials must be > 0".into());
        }
        Ok(())
    }

    /// The shared [`RunSpec`] for one (m, k) sweep point of this preset —
    /// ready to hand to any `protocol::by_name(&self.protocol)` instance.
    pub fn run_spec(&self, m: usize, k: usize) -> RunSpec {
        let mut spec = RunSpec::new(m, k)
            .algorithm(&self.algorithm)
            .partition(self.partition)
            .multiplicity(self.multiplicity)
            .placement(self.placement)
            .recovery(self.recovery)
            .checkpoint_every(self.checkpoint_every)
            .threads(self.threads)
            .batch(self.batch)
            .epsilon(self.epsilon)
            .seed(self.seed);
        if self.local_eval {
            spec = spec.local();
        }
        // assign directly: the `.fanout()` builder clamps to >= 2, which
        // would destroy the 0 = protocol-default sentinel
        spec.fanout = self.fanout;
        spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            name = "fig4a"
            workload = "tiny_images"
            protocol = "multiround"
            n = 10000
            d = 32
            ks = [50]
            ms = [2, 4, 6, 8, 10]
            alphas = [0.5, 1.0, 2.0]
            local_eval = false
            algorithm = "lazy"
            partition = "balanced"
            threads = 4
            trials = 5
            seed = 42
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig4a");
        assert_eq!(cfg.workload, Workload::TinyImages);
        assert_eq!(cfg.protocol, "multiround");
        assert_eq!(cfg.ms, vec![2, 4, 6, 8, 10]);
        assert_eq!(cfg.alphas, vec![0.5, 1.0, 2.0]);
        assert_eq!(cfg.partition, PartitionStrategy::Balanced);
        assert_eq!(cfg.threads, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml("bogus = 1").is_err());
    }

    #[test]
    fn trace_key_parses() {
        let cfg = ExperimentConfig::from_toml(r#"trace = "/tmp/run.trace.json""#).unwrap();
        assert_eq!(cfg.trace.as_deref(), Some("/tmp/run.trace.json"));
        assert_eq!(ExperimentConfig::from_toml("").unwrap().trace, None);
        assert!(ExperimentConfig::from_toml("trace = 3").is_err());
    }

    #[test]
    fn serve_section_is_tolerated_not_parsed() {
        // one preset can carry experiment + [serve] sections; each parser
        // owns its keys (serve's schema is serve::ServeSpec's business)
        let text = "protocol = \"greedi\"\n\n[serve]\naddr = \"127.0.0.1:0\"\nmax_concurrency = 2\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.protocol, "greedi");
        let spec = crate::serve::ServeSpec::from_toml(text).unwrap();
        assert_eq!((spec.addr.as_str(), spec.max_concurrency), ("127.0.0.1:0", 2));
    }

    #[test]
    fn unknown_workload_rejected() {
        assert!(ExperimentConfig::from_toml(r#"workload = "marsrover""#).is_err());
    }

    #[test]
    fn zero_k_rejected() {
        assert!(ExperimentConfig::from_toml("ks = [0]").is_err());
    }

    #[test]
    fn bad_algorithm_rejected() {
        assert!(ExperimentConfig::from_toml(r#"algorithm = "quantum""#).is_err());
    }

    #[test]
    fn bad_protocol_rejected() {
        assert!(ExperimentConfig::from_toml(r#"protocol = "carrier_pigeon""#).is_err());
    }

    #[test]
    fn every_registry_protocol_accepted() {
        for name in crate::coordinator::protocol::NAMES {
            let cfg =
                ExperimentConfig::from_toml(&format!("protocol = \"{name}\"")).unwrap();
            assert_eq!(cfg.protocol, name);
        }
    }

    #[test]
    fn bad_partition_rejected() {
        assert!(ExperimentConfig::from_toml(r#"partition = "psychic""#).is_err());
    }

    #[test]
    fn stream_preset_parses_and_reaches_spec() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            protocol = "stream_greedi"
            batch = 64
            epsilon = 0.2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.protocol, "stream_greedi");
        assert_eq!(cfg.batch, 64);
        assert!((cfg.epsilon - 0.2).abs() < 1e-12);
        let spec = cfg.run_spec(4, 10);
        assert_eq!(spec.batch, 64);
        assert!((spec.epsilon - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bad_stream_keys_rejected() {
        assert!(ExperimentConfig::from_toml("batch = 0").is_err());
        assert!(ExperimentConfig::from_toml("epsilon = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("epsilon = 1.5").is_err());
    }

    #[test]
    fn fanout_key_parses_validates_and_reaches_spec() {
        // explicit fan-in survives the preset -> RunSpec hop un-clamped
        let cfg = ExperimentConfig::from_toml("fanout = 4").unwrap();
        assert_eq!(cfg.fanout, 4);
        assert_eq!(cfg.run_spec(8, 10).fanout, 4);
        // default is the 0 sentinel (protocol picks flat vs binary tree)
        let bare = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(bare.fanout, 0);
        assert_eq!(bare.run_spec(8, 10).fanout, 0);
        // a 1-ary "tree" never terminates; reject it loudly instead of
        // silently clamping like the builder does
        let err = ExperimentConfig::from_toml("fanout = 1").unwrap_err();
        assert!(err.contains("fanout"), "{err}");
        assert!(ExperimentConfig::from_toml(r#"fanout = "wide""#).is_err());
    }

    #[test]
    fn fault_tolerance_keys_parse_and_reach_spec() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            multiplicity = 2
            placement = "distinct_domains"
            recovery = "resume"
            checkpoint_every = 8
            "#,
        )
        .unwrap();
        assert_eq!(cfg.multiplicity, 2);
        assert_eq!(cfg.placement, PlacementPolicy::DistinctDomains);
        assert_eq!(cfg.recovery, RecoveryPolicy::Resume);
        assert_eq!(cfg.checkpoint_every, 8);
        let spec = cfg.run_spec(5, 10);
        assert_eq!(spec.multiplicity, 2);
        assert_eq!(spec.placement, PlacementPolicy::DistinctDomains);
        assert_eq!(spec.recovery, RecoveryPolicy::Resume);
        assert_eq!(spec.checkpoint_every, 8);
        // defaults reproduce the placement-agnostic, checkpoint-free runs
        let bare = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(bare.placement, PlacementPolicy::Anywhere);
        assert_eq!(bare.checkpoint_every, 0);
    }

    #[test]
    fn bad_fault_tolerance_keys_rejected() {
        assert!(ExperimentConfig::from_toml("multiplicity = 0").is_err());
        assert!(ExperimentConfig::from_toml(r#"recovery = "pray""#).is_err());
        assert!(ExperimentConfig::from_toml(r#"placement = "wherever""#).is_err());
        assert!(ExperimentConfig::from_toml(r#"checkpoint_every = "lots""#).is_err());
    }

    #[test]
    fn run_spec_carries_preset_fields() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            protocol = "greedy_scaling"
            algorithm = "greedy"
            local_eval = true
            partition = "contiguous"
            threads = 3
            seed = 7
            "#,
        )
        .unwrap();
        let spec = cfg.run_spec(6, 12);
        assert_eq!((spec.m, spec.k), (6, 12));
        assert_eq!(spec.algorithm, "greedy");
        assert!(spec.local_eval);
        assert_eq!(spec.partition, PartitionStrategy::Contiguous);
        assert_eq!(spec.threads, 3);
        assert_eq!(spec.seed, 7);
    }

    #[test]
    fn workload_roundtrip() {
        for w in [
            Workload::TinyImages,
            Workload::Parkinsons,
            Workload::Yahoo,
            Workload::SocialCut,
            Workload::Accidents,
            Workload::Kosarak,
        ] {
            assert_eq!(Workload::parse(w.label()), Some(w));
        }
    }
}
