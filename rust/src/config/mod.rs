//! Typed experiment configuration, loadable from `configs/*.toml` presets
//! (via the `util::toml` subset parser) and overridable from the CLI.

use std::path::Path;

pub use crate::coordinator::greedi::{GreediConfig, PartitionStrategy};
use crate::util::toml;

/// Which scenario an experiment run drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// Exemplar clustering on tiny-image-like vectors (§6.1).
    TinyImages,
    /// GP active-set selection on Parkinsons-like vectors (§6.2).
    Parkinsons,
    /// GP active-set selection on Yahoo-like 6-d features (§6.2 large).
    Yahoo,
    /// Max-cut on a social graph (§6.3).
    SocialCut,
    /// Coverage on Accidents-like transactions (§6.4).
    Accidents,
    /// Coverage on Kosarak-like transactions (§6.4).
    Kosarak,
}

impl Workload {
    pub fn parse(s: &str) -> Option<Workload> {
        Some(match s {
            "tiny_images" => Workload::TinyImages,
            "parkinsons" => Workload::Parkinsons,
            "yahoo" => Workload::Yahoo,
            "social_cut" => Workload::SocialCut,
            "accidents" => Workload::Accidents,
            "kosarak" => Workload::Kosarak,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Workload::TinyImages => "tiny_images",
            Workload::Parkinsons => "parkinsons",
            Workload::Yahoo => "yahoo",
            Workload::SocialCut => "social_cut",
            Workload::Accidents => "accidents",
            Workload::Kosarak => "kosarak",
        }
    }
}

/// Full experiment description (what one `greedi <figN>` invocation runs).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub workload: Workload,
    /// Ground set size (scaled-down stand-in for the paper's corpus).
    pub n: usize,
    /// Feature dimension (point workloads).
    pub d: usize,
    /// Budgets to sweep.
    pub ks: Vec<usize>,
    /// Machine counts to sweep.
    pub ms: Vec<usize>,
    /// κ/k over-selection factors to sweep (GreeDi curves per α).
    pub alphas: Vec<f64>,
    /// Local (decomposable) evaluation mode.
    pub local_eval: bool,
    /// Per-machine algorithm.
    pub algorithm: String,
    /// Repetitions (figures show mean ± std).
    pub trials: usize,
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "custom".into(),
            workload: Workload::TinyImages,
            n: 1000,
            d: 8,
            ks: vec![50],
            ms: vec![5],
            alphas: vec![1.0],
            local_eval: false,
            algorithm: "lazy".into(),
            trials: 3,
            seed: 42,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file; unknown keys are rejected so presets
    /// cannot silently drift from the schema.
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_toml(&text).map_err(|e| format!("{path:?}: {e}"))
    }

    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = ExperimentConfig::default();
        for (key, value) in &doc.entries {
            match key.as_str() {
                "name" => cfg.name = value.as_str().ok_or("name: string")?.into(),
                "workload" => {
                    let s = value.as_str().ok_or("workload: string")?;
                    cfg.workload =
                        Workload::parse(s).ok_or_else(|| format!("unknown workload {s}"))?;
                }
                "n" => cfg.n = value.as_usize().ok_or("n: int")?,
                "d" => cfg.d = value.as_usize().ok_or("d: int")?,
                "ks" => cfg.ks = value.as_usize_array().ok_or("ks: [int]")?,
                "ms" => cfg.ms = value.as_usize_array().ok_or("ms: [int]")?,
                "alphas" => {
                    cfg.alphas = match value {
                        toml::Value::Array(xs) => xs
                            .iter()
                            .map(|v| v.as_f64().ok_or("alphas: [float]"))
                            .collect::<Result<_, _>>()?,
                        _ => return Err("alphas: [float]".into()),
                    }
                }
                "local_eval" => cfg.local_eval = value.as_bool().ok_or("local_eval: bool")?,
                "algorithm" => cfg.algorithm = value.as_str().ok_or("algorithm: string")?.into(),
                "trials" => cfg.trials = value.as_usize().ok_or("trials: int")?,
                "seed" => cfg.seed = value.as_i64().ok_or("seed: int")? as u64,
                other => return Err(format!("unknown config key {other:?}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("n must be > 0".into());
        }
        if self.ks.is_empty() || self.ms.is_empty() {
            return Err("ks and ms must be non-empty".into());
        }
        if self.ks.iter().any(|&k| k == 0) {
            return Err("all ks must be > 0".into());
        }
        if self.ms.iter().any(|&m| m == 0) {
            return Err("all ms must be > 0".into());
        }
        if crate::algorithms::by_name(&self.algorithm).is_none() {
            return Err(format!("unknown algorithm {:?}", self.algorithm));
        }
        if self.trials == 0 {
            return Err("trials must be > 0".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            name = "fig4a"
            workload = "tiny_images"
            n = 10000
            d = 32
            ks = [50]
            ms = [2, 4, 6, 8, 10]
            alphas = [0.5, 1.0, 2.0]
            local_eval = false
            algorithm = "lazy"
            trials = 5
            seed = 42
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig4a");
        assert_eq!(cfg.workload, Workload::TinyImages);
        assert_eq!(cfg.ms, vec![2, 4, 6, 8, 10]);
        assert_eq!(cfg.alphas, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml("bogus = 1").is_err());
    }

    #[test]
    fn unknown_workload_rejected() {
        assert!(ExperimentConfig::from_toml(r#"workload = "marsrover""#).is_err());
    }

    #[test]
    fn zero_k_rejected() {
        assert!(ExperimentConfig::from_toml("ks = [0]").is_err());
    }

    #[test]
    fn bad_algorithm_rejected() {
        assert!(ExperimentConfig::from_toml(r#"algorithm = "quantum""#).is_err());
    }

    #[test]
    fn workload_roundtrip() {
        for w in [
            Workload::TinyImages,
            Workload::Parkinsons,
            Workload::Yahoo,
            Workload::SocialCut,
            Workload::Accidents,
            Workload::Kosarak,
        ] {
            assert_eq!(Workload::parse(w.label()), Some(w));
        }
    }
}
