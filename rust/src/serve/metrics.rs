//! Query-latency metrics surface: bounded ring buffers of per-query
//! timings with p50/p99/qps summaries.
//!
//! Every completed query records two durations — time spent **queued** in
//! admission and **end-to-end latency** (admission wait + protocol run) —
//! into fixed-capacity ring buffers, so a long-lived daemon's memory stays
//! bounded while the percentiles track the recent window. The `stats` wire
//! op serializes a [`LatencySnapshot`] (via [`util::stats`] nearest-rank
//! percentiles), and [`ServeMetrics::to_json`] is exactly what `bench_serve`
//! dumps into the `GREEDI_BENCH_JSON` trail so qps/p99 join the per-op
//! delta table in CI.
//!
//! qps is lifetime throughput: completed queries over the wall-clock span
//! from the first recorded completion to the last (a single query reports
//! its own latency as the span). Error replies count separately and never
//! pollute the latency window.
//!
//! [`util::stats`]: crate::util::stats

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::{percentile, summarize};

/// Default ring capacity: enough to hold the recent window of any realistic
/// closed-loop load without unbounded growth.
pub const DEFAULT_RING: usize = 1024;

/// Fixed-capacity overwrite-oldest sample buffer.
struct Ring {
    buf: Vec<f64>,
    cap: usize,
    at: usize,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { buf: Vec::with_capacity(cap.max(1)), cap: cap.max(1), at: 0 }
    }

    fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.at] = x;
        }
        self.at = (self.at + 1) % self.cap;
    }

    fn samples(&self) -> Vec<f64> {
        self.buf.clone()
    }
}

struct Windows {
    latency_us: Ring,
    queued_us: Ring,
    first_done: Option<Instant>,
    last_done: Option<Instant>,
}

/// Percentile summary of one ring (all values in microseconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySnapshot {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LatencySnapshot {
    fn of(xs: &[f64]) -> LatencySnapshot {
        if xs.is_empty() {
            return LatencySnapshot::default();
        }
        let s = summarize(xs);
        LatencySnapshot {
            count: s.n,
            mean_us: s.mean,
            p50_us: percentile(xs, 50.0),
            p99_us: percentile(xs, 99.0),
            max_us: s.max,
        }
    }

    fn to_json(self) -> Json {
        Json::obj([
            ("count", Json::num(self.count as f64)),
            ("mean_us", Json::num(self.mean_us)),
            ("p50_us", Json::num(self.p50_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("max_us", Json::num(self.max_us)),
        ])
    }
}

/// Everything the `stats` wire op reports about timings.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub errors: u64,
    pub qps: f64,
    pub latency: LatencySnapshot,
    pub queued: LatencySnapshot,
}

/// Shared recorder, one per server.
pub struct ServeMetrics {
    windows: Mutex<Windows>,
    completed: AtomicU64,
    errors: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new(DEFAULT_RING)
    }
}

impl ServeMetrics {
    pub fn new(ring: usize) -> ServeMetrics {
        ServeMetrics {
            windows: Mutex::new(Windows {
                latency_us: Ring::new(ring),
                queued_us: Ring::new(ring),
                first_done: None,
                last_done: None,
            }),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        }
    }

    /// Record one successful query: `queued_us` in admission, `latency_us`
    /// end to end.
    pub fn record_query(&self, queued_us: f64, latency_us: f64) {
        let now = Instant::now();
        let mut w = self.windows.lock().unwrap();
        w.latency_us.push(latency_us);
        w.queued_us.push(queued_us);
        if w.first_done.is_none() {
            w.first_done = Some(now);
        }
        w.last_done = Some(now);
        drop(w);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a query that ended in an error reply (shed, bad request, …).
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let w = self.windows.lock().unwrap();
        let latency = w.latency_us.samples();
        let queued = w.queued_us.samples();
        let completed = self.completed.load(Ordering::Relaxed);
        let span_s = match (w.first_done, w.last_done) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        // one query has zero span: fall back to its own latency
        let eff_s = if span_s > 0.0 {
            span_s
        } else {
            latency.first().map(|us| us / 1e6).unwrap_or(0.0)
        };
        let qps = if eff_s > 0.0 { completed as f64 / eff_s } else { 0.0 };
        MetricsSnapshot {
            completed,
            errors: self.errors.load(Ordering::Relaxed),
            qps,
            latency: LatencySnapshot::of(&latency),
            queued: LatencySnapshot::of(&queued),
        }
    }

    /// The `stats` reply body (latency section); also embedded in
    /// `BENCH_serve.json` by the load bench.
    pub fn to_json(&self) -> Json {
        let s = self.snapshot();
        Json::obj([
            ("completed", Json::num(s.completed as f64)),
            ("errors", Json::num(s.errors as f64)),
            ("qps", Json::num(s.qps)),
            ("latency", s.latency.to_json()),
            ("queued", s.queued.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest() {
        let mut r = Ring::new(3);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            r.push(x);
        }
        let mut got = r.samples();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn empty_snapshot_is_finite_zero() {
        let m = ServeMetrics::default();
        let s = m.snapshot();
        assert_eq!((s.completed, s.errors), (0, 0));
        assert_eq!(s.qps, 0.0);
        assert_eq!(s.latency.count, 0);
        assert_eq!(s.latency.p99_us, 0.0, "empty window must not report NaN");
    }

    #[test]
    fn percentiles_over_recorded_window() {
        let m = ServeMetrics::new(256);
        for i in 1..=100 {
            m.record_query(i as f64 / 10.0, i as f64 * 100.0);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.latency.count, 100);
        assert_eq!(s.latency.p50_us, 5000.0);
        assert_eq!(s.latency.p99_us, 9900.0, "nearest-rank p99 of 100..10000 step 100");
        assert_eq!(s.latency.max_us, 10000.0);
        assert_eq!(s.queued.p50_us, 5.0);
        assert!(s.qps > 0.0, "span or single-latency fallback must give positive qps");
    }

    #[test]
    fn errors_do_not_enter_latency_window() {
        let m = ServeMetrics::default();
        m.record_query(1.0, 50.0);
        m.record_error();
        m.record_error();
        let s = m.snapshot();
        assert_eq!((s.completed, s.errors), (1, 2));
        assert_eq!(s.latency.count, 1);
        assert_eq!(s.latency.p50_us, 50.0);
        // single completion: qps falls back to its own latency (50us -> 20k qps)
        assert!((s.qps - 20000.0).abs() < 1e-6, "qps={}", s.qps);
    }

    #[test]
    fn stats_json_shape() {
        let m = ServeMetrics::default();
        m.record_query(2.0, 100.0);
        let j = m.to_json();
        assert_eq!(j.get("completed").and_then(|v| v.as_f64()), Some(1.0));
        let lat = j.get("latency").unwrap();
        assert_eq!(lat.get("p50_us").and_then(|v| v.as_f64()), Some(100.0));
        assert_eq!(lat.get("p99_us").and_then(|v| v.as_f64()), Some(100.0));
        assert!(j.get("qps").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }
}
