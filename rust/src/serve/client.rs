//! Blocking NDJSON client for the selection daemon — the counterpart the
//! `query` subcommand, the load bench and the integration tests share.
//!
//! One request in flight per connection: each call writes one line, then
//! blocks for one reply line and decodes it into `Ok(result)` or the
//! server's typed [`WireError`]. Transport failures surface as
//! [`ErrorKind::Internal`] so callers handle exactly one error type.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::coordinator::protocol::RunSpec;
use crate::util::json::Json;

use super::wire::{self, ErrorKind, QueryReply, WireError};

/// A connected client. Requests carry a per-connection incrementing `id`
/// that the server echoes, so replies are self-describing in logs.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    next_id: u64,
}

fn io_err(what: &str, e: std::io::Error) -> WireError {
    WireError::new(ErrorKind::Internal, format!("{what}: {e}"))
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client, WireError> {
        let writer = TcpStream::connect(addr).map_err(|e| io_err("connect", e))?;
        let read_half = writer.try_clone().map_err(|e| io_err("clone stream", e))?;
        Ok(Client { writer, reader: BufReader::new(read_half), next_id: 0 })
    }

    fn call(&mut self, line: String) -> Result<Json, WireError> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| io_err("send", e))?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(|e| io_err("recv", e))?;
        if n == 0 {
            return Err(WireError::new(ErrorKind::Internal, "server closed the connection"));
        }
        wire::parse_reply(reply.trim())
    }

    fn id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    pub fn ping(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("ping", id))
    }

    pub fn stats(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("stats", id))
    }

    pub fn datasets(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("datasets", id))
    }

    /// Pre-fill the named (or default) dataset's singleton cache.
    pub fn warm(&mut self, dataset: Option<&str>) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::warm_line(dataset, id))
    }

    /// Pull `count` more stream elements into the dataset (drift mutation).
    pub fn advance(&mut self, dataset: Option<&str>, count: usize) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::advance_line(dataset, count, id))
    }

    /// Run one selection query and decode the typed reply.
    pub fn query(
        &mut self,
        protocol: &str,
        dataset: Option<&str>,
        spec: &RunSpec,
    ) -> Result<QueryReply, WireError> {
        let id = self.id();
        let result = self.call(wire::query_line(protocol, dataset, spec, id))?;
        QueryReply::from_json(&result)
    }

    /// Ask the daemon to stop (it still answers this request).
    pub fn shutdown(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("shutdown", id))
    }
}
