//! Blocking NDJSON client for the selection daemon — the counterpart the
//! `query` subcommand, the load bench and the integration tests share.
//!
//! One request in flight per connection: each call writes one line, then
//! blocks for one reply line and decodes it into `Ok(result)` or the
//! server's typed [`WireError`]. Transport failures surface as
//! [`ErrorKind::Internal`] so callers handle exactly one error type.
//!
//! ## Retries
//!
//! [`Client::connect_retrying`] layers a bounded, deterministic retry loop
//! over connection establishment and request sends: transient failures
//! (connection refused, reset before any request byte was written) are
//! retried up to [`RetryPolicy::attempts`] times with a capped exponential
//! backoff, then surface as a typed [`ErrorKind::Unavailable`] give-up
//! error. A send that already put bytes on the wire is **never** retried —
//! the server may have executed the request, and replaying a non-idempotent
//! op (`advance`, `shutdown`) would double-apply it. Read failures fall in
//! the same category for the same reason. [`Client::connect`] keeps the
//! single-attempt behavior.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::coordinator::protocol::RunSpec;
use crate::util::json::Json;

use super::wire::{self, ErrorKind, QueryReply, WireError};

/// Bounded deterministic retry schedule for transient transport failures:
/// attempt i sleeps `min(base_delay_ms << i, max_delay_ms)` before the next
/// try. No jitter — retries are reproducible, and the cap keeps the total
/// worst-case wait small (defaults: 10, 20, 40ms ≈ 70ms across 4 attempts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total tries (the first attempt counts; 1 = no retries).
    pub attempts: usize,
    pub base_delay_ms: u64,
    pub max_delay_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 4, base_delay_ms: 10, max_delay_ms: 160 }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (0-based): capped exponential.
    pub fn delay_ms(&self, attempt: usize) -> u64 {
        let factor = 1u64.checked_shl(attempt.min(63) as u32).unwrap_or(u64::MAX);
        self.base_delay_ms.saturating_mul(factor).min(self.max_delay_ms)
    }

    fn sleep(&self, attempt: usize) {
        let ms = self.delay_ms(attempt);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// A connected client. Requests carry a per-connection incrementing `id`
/// that the server echoes, so replies are self-describing in logs.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    next_id: u64,
    /// Reconnect target + schedule; `None` = single-attempt client.
    retry: Option<(String, RetryPolicy)>,
}

fn io_err(what: &str, e: std::io::Error) -> WireError {
    WireError::new(ErrorKind::Internal, format!("{what}: {e}"))
}

fn gave_up(what: &str, tried: usize, last: std::io::Error) -> WireError {
    WireError::new(
        ErrorKind::Unavailable,
        format!("{what}: gave up after {tried} attempts: {last}"),
    )
}

fn connect_once(addr: impl ToSocketAddrs) -> std::io::Result<(TcpStream, BufReader<TcpStream>)> {
    let writer = TcpStream::connect(addr)?;
    let read_half = writer.try_clone()?;
    Ok((writer, BufReader::new(read_half)))
}

/// Outcome of one send attempt, split by whether a retry is safe.
enum SendFailure {
    /// Nothing reached the wire — reconnect + resend cannot double-apply.
    Clean(std::io::Error),
    /// Bytes were written (or the reply read failed): the server may have
    /// executed the request; never retried.
    Dirty(std::io::Error),
}

impl Client {
    /// Single-attempt connect (no retries) — transport errors surface as
    /// [`ErrorKind::Internal`] immediately.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client, WireError> {
        let (writer, reader) = connect_once(addr).map_err(|e| io_err("connect", e))?;
        Ok(Client { writer, reader, next_id: 0, retry: None })
    }

    /// Connect with bounded retries on transient failures, and keep the
    /// policy for later sends: a request whose bytes never reached the wire
    /// reconnects and retries on the same schedule. Gives up with a typed
    /// [`ErrorKind::Unavailable`] error after `policy.attempts` tries.
    pub fn connect_retrying(addr: &str, policy: RetryPolicy) -> Result<Client, WireError> {
        let attempts = policy.attempts.max(1);
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                policy.sleep(attempt - 1);
            }
            match connect_once(addr) {
                Ok((writer, reader)) => {
                    return Ok(Client {
                        writer,
                        reader,
                        next_id: 0,
                        retry: Some((addr.to_string(), policy)),
                    })
                }
                Err(e) => last = Some(e),
            }
        }
        Err(gave_up("connect", attempts, last.expect("attempts >= 1")))
    }

    /// Write the line byte-by-byte so a failure is classifiable: an error
    /// before the first byte leaves the stream clean (retry-safe), any
    /// later failure is dirty.
    fn send_line(&mut self, line: &str) -> Result<(), SendFailure> {
        let buf = format!("{line}\n");
        let bytes = buf.as_bytes();
        let mut written = 0usize;
        while written < bytes.len() {
            match self.writer.write(&bytes[written..]) {
                Ok(0) => {
                    let e = std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "wrote 0 bytes",
                    );
                    return Err(if written == 0 {
                        SendFailure::Clean(e)
                    } else {
                        SendFailure::Dirty(e)
                    });
                }
                Ok(n) => written += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) if written == 0 => return Err(SendFailure::Clean(e)),
                Err(e) => return Err(SendFailure::Dirty(e)),
            }
        }
        self.writer.flush().map_err(SendFailure::Dirty)
    }

    fn call(&mut self, line: String) -> Result<Json, WireError> {
        let mut attempt = 0usize;
        loop {
            match self.send_line(&line) {
                Ok(()) => break,
                Err(SendFailure::Dirty(e)) => return Err(io_err("send", e)),
                Err(SendFailure::Clean(e)) => {
                    let Some((addr, policy)) = self.retry.clone() else {
                        return Err(io_err("send", e));
                    };
                    attempt += 1;
                    if attempt >= policy.attempts.max(1) {
                        return Err(gave_up("send", attempt, e));
                    }
                    policy.sleep(attempt - 1);
                    // the old stream is dead; a fresh connection retries the
                    // not-yet-sent request without replay risk
                    let (writer, reader) =
                        connect_once(addr.as_str()).map_err(|e| io_err("reconnect", e))?;
                    self.writer = writer;
                    self.reader = reader;
                }
            }
        }
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(|e| io_err("recv", e))?;
        if n == 0 {
            return Err(WireError::new(ErrorKind::Internal, "server closed the connection"));
        }
        wire::parse_reply(reply.trim())
    }

    fn id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    pub fn ping(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("ping", id))
    }

    pub fn stats(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("stats", id))
    }

    pub fn datasets(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("datasets", id))
    }

    /// Pre-fill the named (or default) dataset's singleton cache.
    pub fn warm(&mut self, dataset: Option<&str>) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::warm_line(dataset, id))
    }

    /// Pull `count` more stream elements into the dataset (drift mutation).
    pub fn advance(&mut self, dataset: Option<&str>, count: usize) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::advance_line(dataset, count, id))
    }

    /// Run one selection query and decode the typed reply.
    pub fn query(
        &mut self,
        protocol: &str,
        dataset: Option<&str>,
        spec: &RunSpec,
    ) -> Result<QueryReply, WireError> {
        let id = self.id();
        let result = self.call(wire::query_line(protocol, dataset, spec, id))?;
        QueryReply::from_json(&result)
    }

    /// Ask the daemon to stop (it still answers this request).
    pub fn shutdown(&mut self) -> Result<Json, WireError> {
        let id = self.id();
        self.call(wire::simple_line("shutdown", id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;
    use std::time::Duration;

    #[test]
    fn backoff_is_deterministic_and_capped() {
        let p = RetryPolicy { attempts: 6, base_delay_ms: 10, max_delay_ms: 160 };
        assert_eq!(
            (0..6).map(|i| p.delay_ms(i)).collect::<Vec<_>>(),
            vec![10, 20, 40, 80, 160, 160],
        );
        // huge attempt indices must not overflow the shift
        assert_eq!(p.delay_ms(1_000), 160);
        let d = RetryPolicy::default();
        assert_eq!(d.attempts, 4);
        assert_eq!(d.delay_ms(0), 10);
    }

    #[test]
    fn connect_retrying_gives_up_with_typed_error() {
        // a freshly bound-then-dropped port refuses connections
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let policy = RetryPolicy { attempts: 2, base_delay_ms: 1, max_delay_ms: 2 };
        let err = Client::connect_retrying(&addr, policy).unwrap_err();
        assert_eq!(err.kind, ErrorKind::Unavailable, "{}", err.msg);
        assert!(err.msg.contains("after 2 attempts"), "{}", err.msg);
        // the single-attempt constructor keeps the legacy Internal mapping
        let err = Client::connect(addr.as_str()).unwrap_err();
        assert_eq!(err.kind, ErrorKind::Internal);
    }

    #[test]
    fn connect_retrying_outlasts_a_flaky_listener() {
        // Reserve a port, free it (attempt 1 gets refused), then bring the
        // listener up mid-schedule: the retry loop must connect and the
        // request must round-trip.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let server = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            let listener = TcpListener::bind(addr).expect("rebind freed port");
            let (mut sock, _) = listener.accept().unwrap();
            let mut lines = BufReader::new(sock.try_clone().unwrap());
            let mut req = String::new();
            lines.read_line(&mut req).unwrap();
            assert!(req.contains("ping"), "unexpected request {req:?}");
            sock.write_all(b"{\"ok\": true, \"id\": 1, \"result\": {\"pong\": true}}\n")
                .unwrap();
        });
        let policy = RetryPolicy { attempts: 10, base_delay_ms: 20, max_delay_ms: 40 };
        let mut client =
            Client::connect_retrying(&addr.to_string(), policy).expect("retries reach the listener");
        let pong = client.ping().expect("ping round-trips");
        assert_eq!(pong.get("pong").and_then(|v| v.as_bool()), Some(true));
        server.join().unwrap();
    }
}
