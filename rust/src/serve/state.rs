//! Warm server state: the dataset registry and per-dataset objective
//! caches that make a resident daemon worth having.
//!
//! A batch CLI reloads and re-prices everything per invocation; the serve
//! subsystem keeps three things alive across queries instead:
//!
//! 1. **Datasets** — registered once as [`Arc<Dataset>`], shared by every
//!    query (the persistent `util::executor` pool and the objectives'
//!    packed windows stay warm with them).
//! 2. **Singleton-gain caches** — the streaming sieve prices every arriving
//!    batch through [`SubmodularFn::singleton_gains`], and a singleton
//!    value `f({e})` is a pure per-element function (gains from ∅ — the
//!    engine harness asserts `singleton_gains == fresh per-element gains`
//!    bit-wise). So the server computes the full-ground vector once per
//!    dataset version and answers every later ladder restart by indexing
//!    into it: `stream_greedi` queries after the first skip the whole
//!    pricing pass. Values are **bit-identical** to a cold run by the
//!    engine's determinism contract (per-element independence + thread
//!    invariance), which `tests/integration_serve.rs` asserts end-to-end.
//! 3. **Arrival order** — a streaming dataset keeps its one-pass
//!    [`StreamSource`] attached; `advance` pulls the next elements into the
//!    visible window (drift: the served corpus evolves), bumps the dataset
//!    version and retires the now-stale singleton cache. Snapshots taken by
//!    in-flight queries keep the version they started with.
//!
//! Element ids in query solutions index the dataset's **current arrival
//! order** (identity for statically registered datasets).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::{FacilityProblem, Problem};
use crate::data::Dataset;
use crate::objective::SubmodularFn;
use crate::stream::StreamSource;
use crate::util::rng::Rng;

/// Aggregate singleton-cache counters (stats surface).
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

/// One dataset version's lazily filled full-ground singleton-gain vector.
/// A fresh cell is installed on every mutation; snapshots hold the cell
/// matching their data, so a drifted dataset can never serve stale gains.
pub struct SingletonCell {
    slot: Mutex<Option<Arc<Vec<f64>>>>,
}

impl SingletonCell {
    fn new() -> Arc<SingletonCell> {
        Arc::new(SingletonCell { slot: Mutex::new(None) })
    }

    pub fn is_warm(&self) -> bool {
        self.slot.lock().unwrap().is_some()
    }

    /// Return the cached vector, filling it via `fill` on first use. The
    /// lock is held across the fill so concurrent first queries compute the
    /// vector once, not once each (they serialize on the fill; every later
    /// hit is a lock-and-clone).
    fn get_or_fill(
        &self,
        stats: &CacheStats,
        fill: impl FnOnce() -> Vec<f64>,
    ) -> Arc<Vec<f64>> {
        let mut slot = self.slot.lock().unwrap();
        match &*slot {
            Some(v) => {
                stats.hits.fetch_add(1, Ordering::Relaxed);
                Arc::clone(v)
            }
            None => {
                stats.misses.fetch_add(1, Ordering::Relaxed);
                let v = Arc::new(fill());
                *slot = Some(Arc::clone(&v));
                v
            }
        }
    }
}

struct EntryState {
    /// Visible backing-row ids, in arrival order.
    order: Vec<usize>,
    version: u64,
    /// Materialized current view (`backing.subset(&order)`; the backing Arc
    /// itself when the order is the full identity).
    current: Arc<Dataset>,
    cell: Arc<SingletonCell>,
}

struct Entry {
    backing: Arc<Dataset>,
    /// `Some` for streaming datasets — the attached one-pass source that
    /// `advance` keeps draining.
    source: Option<Mutex<Box<dyn StreamSource + Send>>>,
    state: Mutex<EntryState>,
}

/// Listing row for the `datasets` wire op.
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub name: String,
    pub n: usize,
    pub d: usize,
    pub version: u64,
    pub streaming: bool,
    pub warm: bool,
}

/// A consistent view of one dataset version, taken at query start.
/// Concurrent `advance` calls never disturb a snapshot: it keeps the data
/// and singleton cell of the version it saw.
pub struct WarmSnapshot {
    pub name: String,
    pub version: u64,
    pub data: Arc<Dataset>,
    cell: Arc<SingletonCell>,
    stats: Arc<CacheStats>,
}

impl WarmSnapshot {
    /// The warm problem instance a query runs against.
    pub fn problem(&self) -> WarmProblem {
        WarmProblem {
            inner: FacilityProblem::new(&self.data),
            cell: Arc::clone(&self.cell),
            stats: Arc::clone(&self.stats),
        }
    }

    /// Force-fill the singleton cache (the `warm` wire op). Returns the
    /// vector length and whether the cache was already warm.
    pub fn warm(&self, threads: usize) -> (usize, bool) {
        let was_warm = self.cell.is_warm();
        let p = self.problem();
        let f = p.global();
        let ids: Vec<usize> = (0..f.ground_size()).collect();
        let n = f.singleton_gains(&ids, threads).len();
        (n, was_warm)
    }
}

/// The registry: name → warm dataset entry. Shared (`Arc<WarmState>`)
/// between the accept loop, every connection thread, and the CLI.
#[derive(Default)]
pub struct WarmState {
    entries: Mutex<BTreeMap<String, Arc<Entry>>>,
    cache_stats: Arc<CacheStats>,
}

impl WarmState {
    pub fn new() -> WarmState {
        WarmState::default()
    }

    /// Register a static dataset: the full corpus is visible immediately
    /// and `advance` is rejected.
    pub fn register(&self, name: &str, data: Arc<Dataset>) {
        let entry = Entry {
            backing: Arc::clone(&data),
            source: None,
            state: Mutex::new(EntryState {
                order: data.ids(),
                version: 0,
                current: data,
                cell: SingletonCell::new(),
            }),
        };
        self.entries.lock().unwrap().insert(name.to_string(), Arc::new(entry));
    }

    /// Register a streaming dataset: `source` yields backing-row ids in
    /// arrival order (e.g. a [`crate::stream::DriftSource`] for covariate
    /// drift); the first `initial` elements become visible now and
    /// [`WarmState::advance`] pulls more later. Returns the visible count.
    pub fn register_streaming(
        &self,
        name: &str,
        backing: Arc<Dataset>,
        mut source: Box<dyn StreamSource + Send>,
        initial: usize,
    ) -> Result<usize, String> {
        let mut order = Vec::new();
        drain_into(&mut order, source.as_mut(), initial)?;
        if order.is_empty() {
            return Err(format!("dataset {name:?}: source yielded no initial elements"));
        }
        let current = materialize(&backing, &order);
        let live = order.len();
        let entry = Entry {
            backing,
            source: Some(Mutex::new(source)),
            state: Mutex::new(EntryState {
                order,
                version: 0,
                current,
                cell: SingletonCell::new(),
            }),
        };
        self.entries.lock().unwrap().insert(name.to_string(), Arc::new(entry));
        Ok(live)
    }

    /// Pull up to `count` more elements from a streaming dataset's source
    /// into the visible window. Bumps the version and retires the singleton
    /// cache (snapshots in flight keep theirs). Returns
    /// `(elements actually added, new live count, new version)` — added may
    /// be short when the source is exhausted.
    pub fn advance(&self, name: &str, count: usize) -> Result<(usize, usize, u64), String> {
        let entry = self.get(name).ok_or_else(|| format!("unknown dataset {name:?}"))?;
        let Some(source) = &entry.source else {
            return Err(format!("dataset {name:?} is static (no attached stream source)"));
        };
        let mut source = source.lock().unwrap();
        let mut fresh = Vec::new();
        drain_into(&mut fresh, source.as_mut(), count)?;
        let mut st = entry.state.lock().unwrap();
        if fresh.is_empty() {
            // exhausted source: report current shape, no version churn
            return Ok((0, st.order.len(), st.version));
        }
        st.order.extend_from_slice(&fresh);
        st.current = materialize(&entry.backing, &st.order);
        st.version += 1;
        st.cell = SingletonCell::new();
        Ok((fresh.len(), st.order.len(), st.version))
    }

    /// Consistent view of a dataset for one query.
    pub fn snapshot(&self, name: &str) -> Option<WarmSnapshot> {
        let entry = self.get(name)?;
        let st = entry.state.lock().unwrap();
        Some(WarmSnapshot {
            name: name.to_string(),
            version: st.version,
            data: Arc::clone(&st.current),
            cell: Arc::clone(&st.cell),
            stats: Arc::clone(&self.cache_stats),
        })
    }

    pub fn list(&self) -> Vec<DatasetInfo> {
        let entries = self.entries.lock().unwrap();
        entries
            .iter()
            .map(|(name, e)| {
                let st = e.state.lock().unwrap();
                DatasetInfo {
                    name: name.clone(),
                    n: st.current.n,
                    d: st.current.d,
                    version: st.version,
                    streaming: e.source.is_some(),
                    warm: st.cell.is_warm(),
                }
            })
            .collect()
    }

    /// `(hits, misses)` of the singleton caches, across all datasets and
    /// versions (the stats surface).
    pub fn cache_counts(&self) -> (u64, u64) {
        (
            self.cache_stats.hits.load(Ordering::Relaxed),
            self.cache_stats.misses.load(Ordering::Relaxed),
        )
    }

    fn get(&self, name: &str) -> Option<Arc<Entry>> {
        self.entries.lock().unwrap().get(name).cloned()
    }
}

fn drain_into(
    order: &mut Vec<usize>,
    source: &mut dyn StreamSource,
    count: usize,
) -> Result<(), String> {
    while order.len() < count {
        let batch = source.next_batch(count - order.len());
        if batch.is_empty() {
            if let Some(err) = source.error() {
                return Err(format!("stream source failed: {err}"));
            }
            break; // exhausted
        }
        order.extend(batch);
    }
    Ok(())
}

/// Materialize the visible view. Reuses the backing Arc when the order is
/// the full identity (the static-registration fast path) instead of
/// copying the corpus.
fn materialize(backing: &Arc<Dataset>, order: &[usize]) -> Arc<Dataset> {
    let identity = order.len() == backing.n && order.iter().enumerate().all(|(i, &e)| i == e);
    if identity {
        Arc::clone(backing)
    } else {
        Arc::new(backing.subset(order))
    }
}

/// The problem a served query runs against: exemplar clustering over the
/// snapshot's data, with the snapshot's singleton cache spliced into the
/// **global** objective. Local/merge objectives are forwarded uncached
/// (their windows vary per shard / per random subset).
pub struct WarmProblem {
    inner: FacilityProblem,
    cell: Arc<SingletonCell>,
    stats: Arc<CacheStats>,
}

impl Problem for WarmProblem {
    fn ground(&self) -> Vec<usize> {
        self.inner.ground()
    }

    fn global(&self) -> Box<dyn SubmodularFn + '_> {
        Box::new(CachedSingletonFn {
            inner: self.inner.global(),
            cell: Arc::clone(&self.cell),
            stats: Arc::clone(&self.stats),
        })
    }

    fn local(&self, shard: &[usize], rng: &mut Rng) -> Box<dyn SubmodularFn + '_> {
        self.inner.local(shard, rng)
    }

    fn merge(&self, m: usize, rng: &mut Rng) -> Box<dyn SubmodularFn + '_> {
        self.inner.merge(m, rng)
    }

    fn has_local_mode(&self) -> bool {
        self.inner.has_local_mode()
    }
}

/// Forwarding wrapper that answers [`SubmodularFn::singleton_gains`] from
/// the warm full-ground cache. Exactness argument: a singleton gain is
/// priced on a fresh empty state, so `f({e})` is a pure function of `e` —
/// independent of which other candidates share the batch (the engine's
/// invariance harness pins `singleton_gains == per-element fresh gains`
/// bit-wise) and of the thread count (the engine's core contract). Indexing
/// a full-ground vector therefore returns the identical bits a cold batched
/// call would.
struct CachedSingletonFn<'a> {
    inner: Box<dyn SubmodularFn + 'a>,
    cell: Arc<SingletonCell>,
    stats: Arc<CacheStats>,
}

impl<'a> SubmodularFn for CachedSingletonFn<'a> {
    fn state(&self) -> Box<dyn crate::objective::State + '_> {
        self.inner.state()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        self.inner.eval(s)
    }

    fn singleton_gains(&self, es: &[usize], threads: usize) -> Vec<f64> {
        let cached = self.cell.get_or_fill(&self.stats, || {
            let all: Vec<usize> = (0..self.inner.ground_size()).collect();
            self.inner.singleton_gains(&all, threads)
        });
        es.iter().map(|&e| cached[e]).collect()
    }

    fn is_monotone(&self) -> bool {
        self.inner.is_monotone()
    }

    fn ground_size(&self) -> usize {
        self.inner.ground_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::stream::{DriftSource, StreamOrder, VecSource};

    fn data(n: usize, seed: u64) -> Arc<Dataset> {
        Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 6), seed))
    }

    #[test]
    fn static_registration_shares_backing_arc() {
        let ws = WarmState::new();
        let ds = data(50, 1);
        ws.register("main", Arc::clone(&ds));
        let snap = ws.snapshot("main").unwrap();
        assert!(Arc::ptr_eq(&snap.data, &ds), "identity view must not copy the corpus");
        assert_eq!(snap.version, 0);
        assert!(ws.snapshot("other").is_none());
        assert!(ws.advance("main", 5).is_err(), "static dataset rejects advance");
    }

    #[test]
    fn cached_singletons_bit_identical_to_cold() {
        let ws = WarmState::new();
        ws.register("main", data(80, 2));
        let snap = ws.snapshot("main").unwrap();
        let cold = FacilityProblem::new(&snap.data);
        let es: Vec<usize> = vec![3, 77, 10, 41];
        let want = cold.global().singleton_gains(&es, 2);
        let p = snap.problem();
        let f = p.global();
        let first = f.singleton_gains(&es, 2); // fills the cache
        let second = f.singleton_gains(&es, 1); // cache hit, different threads
        for i in 0..es.len() {
            assert_eq!(first[i].to_bits(), want[i].to_bits(), "fill mismatch at {i}");
            assert_eq!(second[i].to_bits(), want[i].to_bits(), "hit mismatch at {i}");
        }
        let (hits, misses) = ws.cache_counts();
        assert_eq!((hits, misses), (1, 1));
        assert!(snap.cell.is_warm());
    }

    #[test]
    fn warm_op_prefills() {
        let ws = WarmState::new();
        ws.register("main", data(40, 3));
        let snap = ws.snapshot("main").unwrap();
        let (n, was_warm) = snap.warm(2);
        assert_eq!(n, 40);
        assert!(!was_warm);
        let (_, was_warm) = snap.warm(2);
        assert!(was_warm, "second warm must find the cache filled");
        assert!(ws.list()[0].warm);
    }

    #[test]
    fn streaming_advance_versions_and_invalidates() {
        let ws = WarmState::new();
        let backing = data(60, 4);
        let src = VecSource::shuffled(backing.ids(), 9);
        ws.register_streaming("live", Arc::clone(&backing), Box::new(src), 20).unwrap();
        let s0 = ws.snapshot("live").unwrap();
        assert_eq!(s0.data.n, 20);
        s0.warm(1);
        assert!(s0.cell.is_warm());

        let (added, live, version) = ws.advance("live", 15).unwrap();
        assert_eq!((added, live, version), (15, 35, 1));
        let s1 = ws.snapshot("live").unwrap();
        assert_eq!(s1.data.n, 35);
        assert_eq!(s1.version, 1);
        assert!(!s1.cell.is_warm(), "mutation must retire the singleton cache");
        assert!(s0.cell.is_warm(), "in-flight snapshot keeps its own cache");
        // rows: the first 20 of the new view are the old view exactly
        for i in 0..20 {
            assert_eq!(s0.data.row(i), s1.data.row(i), "prefix stability at {i}");
        }

        // drain past the end: short add, then a no-op
        let (added, live, v) = ws.advance("live", 1000).unwrap();
        assert_eq!((added, live, v), (25, 60, 2));
        let (added, live, v) = ws.advance("live", 10).unwrap();
        assert_eq!((added, live, v), (0, 60, 2), "exhausted source: no version churn");
    }

    #[test]
    fn drift_source_orders_the_window() {
        let ws = WarmState::new();
        let backing = data(30, 5);
        let src = DriftSource::new(&backing, backing.ids(), StreamOrder::Drift);
        ws.register_streaming("drift", Arc::clone(&backing), Box::new(src), 30).unwrap();
        let snap = ws.snapshot("drift").unwrap();
        for i in 1..snap.data.n {
            assert!(
                (snap.data.row(i - 1)[0] as f64) <= (snap.data.row(i)[0] as f64) + 1e-6,
                "drift view must ascend along axis 0"
            );
        }
    }

    #[test]
    fn register_streaming_rejects_empty_source() {
        let ws = WarmState::new();
        let backing = data(10, 6);
        let err = ws
            .register_streaming("x", backing, Box::new(VecSource::new(vec![])), 5)
            .unwrap_err();
        assert!(err.contains("no initial elements"), "{err}");
    }

    #[test]
    fn listing_reports_shape() {
        let ws = WarmState::new();
        ws.register("a", data(12, 7));
        let backing = data(40, 8);
        let src = VecSource::new(backing.ids());
        ws.register_streaming("b", backing, Box::new(src), 16).unwrap();
        let infos = ws.list();
        assert_eq!(infos.len(), 2);
        assert_eq!((infos[0].name.as_str(), infos[0].n, infos[0].streaming), ("a", 12, false));
        assert_eq!((infos[1].name.as_str(), infos[1].n, infos[1].streaming), ("b", 16, true));
    }
}
