//! Always-on selection service: a resident daemon that keeps datasets,
//! the executor pool and objective caches warm, and serves concurrent
//! selection queries over TCP.
//!
//! The paper's GreeDi is a batch protocol; the ROADMAP north star is a
//! system answering selection queries for millions of users. The missing
//! piece is residency: loading the corpus and warming the packed objective
//! windows once, then amortizing them across every query. This subsystem
//! is that piece, zero-dependency like the rest of the crate:
//!
//! * [`wire`] — versioned newline-delimited JSON request/reply schema
//!   (via `util::json`, whose writer this subsystem motivated).
//! * [`state`] — warm dataset registry, full-ground singleton-gain caches
//!   (sieve ladders restart instantly on repeat queries), and dataset
//!   drift through `stream::` sources.
//! * [`admission`] — bounded queue + concurrency cap splitting the
//!   executor budget with the `RunSpec::oracle_threads` model; overload is
//!   shed as a typed error, never buffered unboundedly.
//! * [`metrics`] — per-query latency rings with p50/p99/qps summaries on
//!   the `stats` op and in `BENCH_serve.json`.
//! * [`server`] / [`client`] — thread-per-connection daemon and the
//!   blocking client used by the `query` subcommand, bench and tests;
//!   the client offers bounded deterministic retries for transient
//!   connect/send failures ([`client::RetryPolicy`]).
//!
//! Served results are **bit-identical** to a direct
//! `protocol::by_name(..).run(..)` with the same `RunSpec` and seed: the
//! admission layer only narrows `spec.threads`, which the repo-wide
//! thread-invariance contract guarantees never changes a solution, and the
//! singleton cache returns the same bits batch pricing would (see
//! [`state`]). `tests/integration_serve.rs` asserts this end to end,
//! including under ≥ 8 concurrent clients.
//!
//! # Wire schema (v1)
//!
//! One JSON object per line in each direction. Requests carry
//! `{"v": 1, "op": <string>, "id": <any>}` plus op-specific fields; `id`
//! is echoed verbatim in the reply. Replies are
//! `{"v": 1, "ok": true, "id": ..., "result": {...}}` or
//! `{"v": 1, "ok": false, "id": ..., "error": {"kind": ..., "msg": ...}}`
//! with `kind` one of `bad_request`, `unknown_protocol`,
//! `unknown_dataset`, `overloaded`, `shutting_down`, `internal` (the
//! `unavailable` kind is client-side only: the bounded retry loop in
//! [`client`] exhausted its attempts against an unreachable daemon).
//!
//! | op | request fields | result fields |
//! |---|---|---|
//! | `ping` | — | `op:"pong"`, `uptime_s`, `protocols` |
//! | `stats` | — | `uptime_s`, `admission{..}`, `cache{..}`, `latency{completed,errors,qps,latency{p50_us,p99_us,..},queued{..}}` |
//! | `datasets` | — | `datasets:[{name,n,d,version,streaming,warm}]` |
//! | `warm` | `dataset?` | `dataset`, `version`, `n`, `was_warm` |
//! | `advance` | `dataset?`, `count` | `dataset`, `added`, `live`, `version` |
//! | `query` | `protocol`, `dataset?`, `spec{m,k,..}` | `protocol`, `solution`, `value`, `oracle_calls`, `rounds`, `dataset`, `dataset_version`, `threads_used`, `queued_us`, `latency_us` |
//! | `shutdown` | — | `op:"shutdown"` (then the daemon stops) |
//!
//! `spec` accepts the [`RunSpec`](crate::coordinator::protocol::RunSpec)
//! builder surface: required `m`, `k`; optional `kappa` **or** `alpha`
//! (exclusive), `fanout`, `delta`, `epsilon`, `batch`, `local_eval`,
//! `algorithm`, `threads`, `partition`, `seed`. Unknown fields are
//! rejected — never ignored — so client typos cannot silently change an
//! experiment.
//!
//! # Adding an endpoint
//!
//! 1. **Schema** (`wire.rs`): add a variant to [`wire::Request`], parse it
//!    in `parse_request_doc` (validate everything there — builder panics
//!    must never reach the server), and add a client-side `*_line`
//!    constructor next to [`wire::simple_line`].
//! 2. **Dispatch** (`server.rs`): add the match arm in `handle_line`,
//!    returning `wire::ok_line(id, ...)` or `err_reply(...)` with a typed
//!    [`wire::ErrorKind`]. Long work must go through
//!    [`admission::Admission::admit`] and record into
//!    [`metrics::ServeMetrics`].
//! 3. **Client** (`client.rs`): add the blocking wrapper method.
//! 4. **Prove it** : a round-trip unit test in `wire.rs` (including the
//!    malformed-input rejection path) and an end-to-end case in
//!    `tests/integration_serve.rs`.
//!
//! # Quickstart
//!
//! ```text
//! greedi serve --n 2000 --threads 8          # daemon on 127.0.0.1:7199
//! greedi query --protocol greedi --k 10      # one query from another shell
//! cargo run --example serve_client           # the same dance in code
//! ```

pub mod admission;
pub mod client;
pub mod metrics;
pub mod server;
pub mod state;
pub mod wire;

pub use admission::{split_budget, Admission, AdmissionStats, Permit};
pub use client::{Client, RetryPolicy};
pub use metrics::{LatencySnapshot, MetricsSnapshot, ServeMetrics};
pub use server::{ServeSpec, Server};
pub use state::{DatasetInfo, WarmProblem, WarmSnapshot, WarmState};
pub use wire::{ErrorKind, QueryReply, WireError, WIRE_VERSION};
