//! Wire schema for the selection service: versioned newline-delimited JSON
//! requests and replies over one TCP stream, built entirely on
//! [`util::json`](crate::util::json) (parse + the deterministic writer).
//!
//! ## Framing
//!
//! One request per line, one reply per line, in order. The writer never
//! emits interior newlines (control characters are escaped), so a frame is
//! exactly one `\n`-terminated line.
//!
//! ## Requests
//!
//! Every request is an object with `"v"` (protocol version, currently 1),
//! `"op"`, and an optional `"id"` the server echoes back verbatim so
//! clients can pipeline:
//!
//! ```json
//! {"v":1,"op":"ping","id":7}
//! {"v":1,"op":"stats"}
//! {"v":1,"op":"datasets"}
//! {"v":1,"op":"warm","dataset":"default"}
//! {"v":1,"op":"advance","dataset":"default","count":128}
//! {"v":1,"op":"query","protocol":"greedi","dataset":"default",
//!  "spec":{"m":8,"k":20,"seed":42,"algorithm":"lazy"}}
//! {"v":1,"op":"shutdown"}
//! ```
//!
//! The `"spec"` object mirrors [`RunSpec`] field-for-field (`m` and `k`
//! required; `kappa`, `alpha`, `fanout`, `delta`, `epsilon`, `batch`,
//! `local_eval`, `algorithm`, `threads`, `partition`, `seed` optional, with
//! the builder's defaults). Unknown spec keys are rejected — same
//! strictness as the TOML config, so clients cannot silently drift.
//!
//! ## Replies
//!
//! ```json
//! {"v":1,"ok":true,"id":7,"result":{...}}
//! {"v":1,"ok":false,"id":7,"error":{"kind":"overloaded","msg":"..."}}
//! ```
//!
//! Error kinds are a closed enum ([`ErrorKind`]) so clients can switch on
//! them: `bad_request`, `unknown_protocol`, `unknown_dataset`,
//! `overloaded` (admission shed — retry later), `shutting_down`,
//! `internal`, `unavailable` (client-side: the bounded retry loop gave up).

use std::collections::BTreeMap;

use crate::algorithms;
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::protocol::{self, PartitionStrategy, RunSpec};
use crate::util::json::{self, Json};

/// Wire protocol version. Bump on breaking schema changes; the server
/// rejects mismatched versions with `bad_request` naming both versions.
pub const WIRE_VERSION: u64 = 1;

/// Typed error category carried in every error reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Malformed JSON, missing/invalid fields, or a version mismatch.
    BadRequest,
    /// `protocol` not in `protocol::by_name`.
    UnknownProtocol,
    /// `dataset` not in the warm registry.
    UnknownDataset,
    /// Admission control shed the query (queue full) — retry later.
    Overloaded,
    /// Server is draining; no new work accepted.
    ShuttingDown,
    /// Unexpected server-side failure.
    Internal,
    /// Client-side only: the bounded retry loop exhausted its attempts on
    /// transient connect/send failures (never sent by the server).
    Unavailable,
}

impl ErrorKind {
    pub fn label(&self) -> &'static str {
        match self {
            ErrorKind::BadRequest => "bad_request",
            ErrorKind::UnknownProtocol => "unknown_protocol",
            ErrorKind::UnknownDataset => "unknown_dataset",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::ShuttingDown => "shutting_down",
            ErrorKind::Internal => "internal",
            ErrorKind::Unavailable => "unavailable",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorKind> {
        Some(match s {
            "bad_request" => ErrorKind::BadRequest,
            "unknown_protocol" => ErrorKind::UnknownProtocol,
            "unknown_dataset" => ErrorKind::UnknownDataset,
            "overloaded" => ErrorKind::Overloaded,
            "shutting_down" => ErrorKind::ShuttingDown,
            "internal" => ErrorKind::Internal,
            "unavailable" => ErrorKind::Unavailable,
            _ => return None,
        })
    }
}

/// A structured wire error: closed kind + human message.
#[derive(Debug, Clone)]
pub struct WireError {
    pub kind: ErrorKind,
    pub msg: String,
}

impl WireError {
    pub fn new(kind: ErrorKind, msg: impl Into<String>) -> WireError {
        WireError { kind, msg: msg.into() }
    }

    pub fn bad(msg: impl Into<String>) -> WireError {
        WireError::new(ErrorKind::BadRequest, msg)
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind.label(), self.msg)
    }
}

/// A parsed request.
#[derive(Debug)]
pub enum Request {
    Ping,
    Stats,
    Datasets,
    /// Pre-compute the warm singleton cache for a dataset.
    Warm { dataset: Option<String> },
    /// Advance a streaming dataset by `count` elements (drift mutation).
    Advance { dataset: Option<String>, count: usize },
    Query(Box<QueryRequest>),
    Shutdown,
}

/// One selection query: which protocol, over which warm dataset, under
/// which [`RunSpec`].
#[derive(Debug)]
pub struct QueryRequest {
    pub dataset: Option<String>,
    pub protocol: String,
    pub spec: RunSpec,
}

/// Parse one request line. The `id` (first tuple slot) is recovered even
/// when the request itself is invalid, so error replies can still be
/// correlated; it is `None` when the line is not parseable JSON at all.
pub fn parse_request(line: &str) -> (Option<Json>, Result<Request, WireError>) {
    let doc = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return (None, Err(WireError::bad(format!("invalid json: {e}")))),
    };
    let id = doc.get("id").cloned();
    (id, parse_request_doc(&doc))
}

fn parse_request_doc(doc: &Json) -> Result<Request, WireError> {
    let Json::Obj(_) = doc else {
        return Err(WireError::bad("request must be a json object"));
    };
    match doc.get("v").and_then(|v| v.as_u64()) {
        Some(WIRE_VERSION) => {}
        Some(v) => {
            return Err(WireError::bad(format!(
                "unsupported wire version {v} (server speaks {WIRE_VERSION})"
            )))
        }
        None => return Err(WireError::bad("missing version field \"v\"")),
    }
    let op = doc
        .get("op")
        .and_then(|v| v.as_str())
        .ok_or_else(|| WireError::bad("missing op"))?;
    let dataset = |d: &Json| d.get("dataset").and_then(|v| v.as_str()).map(String::from);
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "datasets" => Ok(Request::Datasets),
        "shutdown" => Ok(Request::Shutdown),
        "warm" => Ok(Request::Warm { dataset: dataset(doc) }),
        "advance" => {
            let count = doc
                .get("count")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| WireError::bad("advance: missing/invalid count"))?;
            Ok(Request::Advance { dataset: dataset(doc), count })
        }
        "query" => {
            let protocol_name = doc
                .get("protocol")
                .and_then(|v| v.as_str())
                .ok_or_else(|| WireError::bad("query: missing protocol"))?
                .to_string();
            if protocol::by_name(&protocol_name).is_none() {
                return Err(WireError::new(
                    ErrorKind::UnknownProtocol,
                    format!(
                        "unknown protocol {protocol_name:?} — known: {}",
                        protocol::NAMES.join(", ")
                    ),
                ));
            }
            let spec_doc = doc
                .get("spec")
                .ok_or_else(|| WireError::bad("query: missing spec"))?;
            let spec = spec_from_json(spec_doc)?;
            Ok(Request::Query(Box::new(QueryRequest {
                dataset: dataset(doc),
                protocol: protocol_name,
                spec,
            })))
        }
        other => Err(WireError::bad(format!("unknown op {other:?}"))),
    }
}

/// Decode a wire `spec` object into a [`RunSpec`]. Strict: `m`/`k`
/// required, every optional field validated with the same predicates the
/// builder asserts (so a bad spec is a typed reply, never a server panic),
/// unknown keys rejected.
pub fn spec_from_json(v: &Json) -> Result<RunSpec, WireError> {
    let Json::Obj(map) = v else {
        return Err(WireError::bad("spec must be a json object"));
    };
    let field = |k: &str| map.get(k);
    let m = field("m")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| WireError::bad("spec: missing/invalid m"))?;
    let k = field("k")
        .and_then(|v| v.as_usize())
        .filter(|&k| k >= 1)
        .ok_or_else(|| WireError::bad("spec: missing/invalid k (need k >= 1)"))?;
    let mut spec = RunSpec::new(m, k);
    for (key, val) in map {
        match key.as_str() {
            "m" | "k" => {}
            "kappa" => {
                spec.kappa = val
                    .as_usize()
                    .filter(|&x| x >= 1)
                    .ok_or_else(|| WireError::bad("spec: kappa must be an integer >= 1"))?;
            }
            "alpha" => {
                let a = val
                    .as_f64()
                    .filter(|&a| a > 0.0)
                    .ok_or_else(|| WireError::bad("spec: alpha must be a positive number"))?;
                if map.contains_key("kappa") {
                    return Err(WireError::bad("spec: give kappa or alpha, not both"));
                }
                spec = spec.alpha(a);
            }
            "fanout" => {
                // 0 = the protocol-default sentinel (flat merge for
                // greedi/stream_greedi, binary tree for multiround).
                spec.fanout = val
                    .as_usize()
                    .filter(|&x| x == 0 || x >= 2)
                    .ok_or_else(|| {
                        WireError::bad("spec: fanout must be 0 (protocol default) or an integer >= 2")
                    })?;
            }
            "delta" => {
                spec.delta = val
                    .as_f64()
                    .filter(|&x| x >= 0.0)
                    .ok_or_else(|| WireError::bad("spec: delta must be >= 0"))?;
            }
            "epsilon" => {
                spec.epsilon = val
                    .as_f64()
                    .filter(|&x| x > 0.0 && x < 1.0)
                    .ok_or_else(|| WireError::bad("spec: epsilon must be in (0, 1)"))?;
            }
            "batch" => {
                spec.batch = val
                    .as_usize()
                    .filter(|&x| x >= 1)
                    .ok_or_else(|| WireError::bad("spec: batch must be an integer >= 1"))?;
            }
            "local_eval" => {
                spec.local_eval = val
                    .as_bool()
                    .ok_or_else(|| WireError::bad("spec: local_eval must be a bool"))?;
            }
            "algorithm" => {
                let name = val
                    .as_str()
                    .ok_or_else(|| WireError::bad("spec: algorithm must be a string"))?;
                if algorithms::by_name(name).is_none() {
                    return Err(WireError::bad(format!("spec: unknown algorithm {name:?}")));
                }
                spec.algorithm = name.to_string();
            }
            "threads" => {
                spec.threads = val
                    .as_usize()
                    .filter(|&x| x >= 1)
                    .ok_or_else(|| WireError::bad("spec: threads must be an integer >= 1"))?;
            }
            "partition" => {
                let s = val
                    .as_str()
                    .ok_or_else(|| WireError::bad("spec: partition must be a string"))?;
                spec.partition = PartitionStrategy::parse(s).ok_or_else(|| {
                    WireError::bad(format!(
                        "spec: unknown partition {s:?} (random|balanced|contiguous)"
                    ))
                })?;
            }
            "seed" => {
                spec.seed = val
                    .as_u64()
                    .ok_or_else(|| WireError::bad("spec: seed must be a non-negative integer"))?;
            }
            other => {
                return Err(WireError::bad(format!("spec: unknown key {other:?}")));
            }
        }
    }
    Ok(spec)
}

/// Encode a [`RunSpec`] as the wire `spec` object (the client half of
/// [`spec_from_json`]; per-round constraint overrides are not expressible
/// on the wire and are dropped).
pub fn spec_to_json(spec: &RunSpec) -> Json {
    Json::obj([
        ("m", Json::num(spec.m as f64)),
        ("k", Json::num(spec.k as f64)),
        ("kappa", Json::num(spec.kappa as f64)),
        ("fanout", Json::num(spec.fanout as f64)),
        ("delta", Json::num(spec.delta)),
        ("epsilon", Json::num(spec.epsilon)),
        ("batch", Json::num(spec.batch as f64)),
        ("local_eval", Json::Bool(spec.local_eval)),
        ("algorithm", Json::str(spec.algorithm.clone())),
        ("threads", Json::num(spec.threads as f64)),
        ("partition", Json::str(spec.partition.label())),
        ("seed", Json::num(spec.seed as f64)),
    ])
}

fn request_shell(op: &str, id: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::num(WIRE_VERSION as f64));
    m.insert("op".to_string(), Json::str(op));
    m.insert("id".to_string(), Json::num(id as f64));
    m
}

/// Client-side: one argument-free request line (`ping`, `stats`, …).
pub fn simple_line(op: &str, id: u64) -> String {
    Json::Obj(request_shell(op, id)).dump()
}

/// Client-side: one `query` request line.
pub fn query_line(protocol_name: &str, dataset: Option<&str>, spec: &RunSpec, id: u64) -> String {
    let mut m = request_shell("query", id);
    m.insert("protocol".to_string(), Json::str(protocol_name));
    if let Some(d) = dataset {
        m.insert("dataset".to_string(), Json::str(d));
    }
    m.insert("spec".to_string(), spec_to_json(spec));
    Json::Obj(m).dump()
}

/// Client-side: one `warm` request line (pre-fill singleton cache).
pub fn warm_line(dataset: Option<&str>, id: u64) -> String {
    let mut m = request_shell("warm", id);
    if let Some(d) = dataset {
        m.insert("dataset".to_string(), Json::str(d));
    }
    Json::Obj(m).dump()
}

/// Client-side: one `advance` request line (drift mutation).
pub fn advance_line(dataset: Option<&str>, count: usize, id: u64) -> String {
    let mut m = request_shell("advance", id);
    if let Some(d) = dataset {
        m.insert("dataset".to_string(), Json::str(d));
    }
    m.insert("count".to_string(), Json::num(count as f64));
    Json::Obj(m).dump()
}

/// Server-side: success reply line.
pub fn ok_line(id: Option<&Json>, result: Json) -> String {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::num(WIRE_VERSION as f64));
    m.insert("ok".to_string(), Json::Bool(true));
    if let Some(id) = id {
        m.insert("id".to_string(), id.clone());
    }
    m.insert("result".to_string(), result);
    Json::Obj(m).dump()
}

/// Server-side: error reply line.
pub fn err_line(id: Option<&Json>, e: &WireError) -> String {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::num(WIRE_VERSION as f64));
    m.insert("ok".to_string(), Json::Bool(false));
    if let Some(id) = id {
        m.insert("id".to_string(), id.clone());
    }
    m.insert(
        "error".to_string(),
        Json::obj([("kind", Json::str(e.kind.label())), ("msg", Json::str(e.msg.clone()))]),
    );
    Json::Obj(m).dump()
}

/// Server-side: the `result` object of a finished query. Built on the
/// canonical [`RunMetrics::to_json`] view (so the wire never hand-formats
/// run fields), with the run's `name` re-keyed as `protocol` and the
/// serve-side envelope fields layered on top. `QueryReply::from_json`
/// reads only its known keys, so the extra run detail (sim_time,
/// stream/fault blocks, …) rides along without breaking old clients.
pub fn query_result_json(
    run: &RunMetrics,
    dataset: &str,
    dataset_version: u64,
    threads_used: usize,
    queued_us: f64,
    latency_us: f64,
) -> Json {
    let Json::Obj(mut m) = run.to_json() else {
        unreachable!("RunMetrics::to_json always yields an object");
    };
    let name = m.remove("name").unwrap_or_else(|| Json::str(run.name.clone()));
    m.insert("protocol".to_string(), name);
    m.insert("dataset".to_string(), Json::str(dataset));
    m.insert("dataset_version".to_string(), Json::num(dataset_version as f64));
    m.insert("threads_used".to_string(), Json::num(threads_used as f64));
    m.insert("queued_us".to_string(), Json::num(queued_us));
    m.insert("latency_us".to_string(), Json::num(latency_us));
    Json::Obj(m)
}

/// Client-side decoded query reply.
#[derive(Debug, Clone)]
pub struct QueryReply {
    pub protocol: String,
    pub solution: Vec<usize>,
    pub value: f64,
    pub oracle_calls: u64,
    pub rounds: usize,
    pub dataset: String,
    pub dataset_version: u64,
    pub threads_used: usize,
    pub queued_us: f64,
    pub latency_us: f64,
}

impl QueryReply {
    pub fn from_json(result: &Json) -> Result<QueryReply, WireError> {
        let get = |k: &str| {
            result
                .get(k)
                .ok_or_else(|| WireError::bad(format!("query result: missing {k}")))
        };
        Ok(QueryReply {
            protocol: get("protocol")?
                .as_str()
                .ok_or_else(|| WireError::bad("query result: protocol"))?
                .to_string(),
            solution: get("solution")?
                .as_usize_arr()
                .ok_or_else(|| WireError::bad("query result: solution"))?,
            value: get("value")?
                .as_f64()
                .ok_or_else(|| WireError::bad("query result: value"))?,
            oracle_calls: get("oracle_calls")?
                .as_u64()
                .ok_or_else(|| WireError::bad("query result: oracle_calls"))?,
            rounds: get("rounds")?
                .as_usize()
                .ok_or_else(|| WireError::bad("query result: rounds"))?,
            dataset: get("dataset")?
                .as_str()
                .ok_or_else(|| WireError::bad("query result: dataset"))?
                .to_string(),
            dataset_version: get("dataset_version")?
                .as_u64()
                .ok_or_else(|| WireError::bad("query result: dataset_version"))?,
            threads_used: get("threads_used")?
                .as_usize()
                .ok_or_else(|| WireError::bad("query result: threads_used"))?,
            queued_us: get("queued_us")?
                .as_f64()
                .ok_or_else(|| WireError::bad("query result: queued_us"))?,
            latency_us: get("latency_us")?
                .as_f64()
                .ok_or_else(|| WireError::bad("query result: latency_us"))?,
        })
    }
}

/// Client-side: decode one reply line into `Ok(result)` or the server's
/// typed error. A malformed reply is surfaced as `bad_request`.
pub fn parse_reply(line: &str) -> Result<Json, WireError> {
    let doc =
        json::parse(line).map_err(|e| WireError::bad(format!("invalid reply json: {e}")))?;
    match doc.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => doc
            .get("result")
            .cloned()
            .ok_or_else(|| WireError::bad("reply: missing result")),
        Some(false) => {
            let err = doc.get("error");
            let kind = err
                .and_then(|e| e.get("kind"))
                .and_then(|k| k.as_str())
                .and_then(ErrorKind::parse)
                .unwrap_or(ErrorKind::Internal);
            let msg = err
                .and_then(|e| e.get("msg"))
                .and_then(|m| m.as_str())
                .unwrap_or("<no message>")
                .to_string();
            Err(WireError::new(kind, msg))
        }
        None => Err(WireError::bad("reply: missing ok field")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips_through_wire_json() {
        let spec = RunSpec::new(8, 20)
            .kappa(30)
            .fanout(4)
            .delta(0.25)
            .epsilon(0.2)
            .batch(64)
            .local()
            .algorithm("greedy")
            .threads(6)
            .partition(PartitionStrategy::Contiguous)
            .seed(1234);
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(back.m, spec.m);
        assert_eq!(back.k, spec.k);
        assert_eq!(back.kappa, spec.kappa);
        assert_eq!(back.fanout, spec.fanout);
        assert_eq!(back.delta.to_bits(), spec.delta.to_bits());
        assert_eq!(back.epsilon.to_bits(), spec.epsilon.to_bits());
        assert_eq!(back.batch, spec.batch);
        assert_eq!(back.local_eval, spec.local_eval);
        assert_eq!(back.algorithm, spec.algorithm);
        assert_eq!(back.threads, spec.threads);
        assert_eq!(back.partition, spec.partition);
        assert_eq!(back.seed, spec.seed);
        // the 0 sentinel (protocol-default fanout) survives the wire too
        let default_spec = RunSpec::new(4, 6);
        assert_eq!(default_spec.fanout, 0);
        let back = spec_from_json(&spec_to_json(&default_spec)).unwrap();
        assert_eq!(back.fanout, 0);
    }

    #[test]
    fn query_line_parses_back() {
        let spec = RunSpec::new(4, 6).seed(9);
        let line = query_line("greedi", Some("main"), &spec, 3);
        let (id, req) = parse_request(&line);
        assert_eq!(id.unwrap().as_u64(), Some(3));
        match req.unwrap() {
            Request::Query(q) => {
                assert_eq!(q.protocol, "greedi");
                assert_eq!(q.dataset.as_deref(), Some("main"));
                assert_eq!((q.spec.m, q.spec.k, q.spec.seed), (4, 6, 9));
            }
            other => panic!("expected query, got {other:?}"),
        }
    }

    #[test]
    fn simple_ops_parse() {
        for (op, want) in [
            ("ping", "Ping"),
            ("stats", "Stats"),
            ("datasets", "Datasets"),
            ("shutdown", "Shutdown"),
        ] {
            let (_, req) = parse_request(&simple_line(op, 0));
            assert!(format!("{:?}", req.unwrap()).starts_with(want), "{op}");
        }
        let (_, req) = parse_request(&advance_line(Some("d"), 7, 1));
        match req.unwrap() {
            Request::Advance { dataset, count } => {
                assert_eq!(dataset.as_deref(), Some("d"));
                assert_eq!(count, 7);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn version_mismatch_rejected_with_id() {
        let (id, req) = parse_request(r#"{"v":99,"op":"ping","id":5}"#);
        assert_eq!(id.unwrap().as_u64(), Some(5), "id recoverable from bad request");
        let err = req.unwrap_err();
        assert_eq!(err.kind, ErrorKind::BadRequest);
        assert!(err.msg.contains("99"), "{}", err.msg);
        let (_, req) = parse_request(r#"{"op":"ping"}"#);
        assert!(req.unwrap_err().msg.contains("version"));
    }

    #[test]
    fn malformed_and_unknown_rejected() {
        assert!(parse_request("not json").1.is_err());
        assert!(parse_request(r#"{"v":1,"op":"fly"}"#).1.is_err());
        assert!(parse_request(r#"{"v":1}"#).1.is_err());
        let (_, req) = parse_request(r#"{"v":1,"op":"query","protocol":"warp","spec":{"m":1,"k":1}}"#);
        assert_eq!(req.unwrap_err().kind, ErrorKind::UnknownProtocol);
    }

    #[test]
    fn spec_validation_paths() {
        let bad = [
            (r#"{"k":5}"#, "m"),
            (r#"{"m":2}"#, "k"),
            (r#"{"m":2,"k":0}"#, "k"),
            (r#"{"m":2,"k":5,"epsilon":1.5}"#, "epsilon"),
            (r#"{"m":2,"k":5,"epsilon":0}"#, "epsilon"),
            (r#"{"m":2,"k":5,"delta":-1}"#, "delta"),
            (r#"{"m":2,"k":5,"fanout":1}"#, "fanout"),
            (r#"{"m":2,"k":5,"batch":0}"#, "batch"),
            (r#"{"m":2,"k":5,"threads":0}"#, "threads"),
            (r#"{"m":2,"k":5,"algorithm":"quantum"}"#, "algorithm"),
            (r#"{"m":2,"k":5,"partition":"psychic"}"#, "partition"),
            (r#"{"m":2,"k":5,"seed":-1}"#, "seed"),
            (r#"{"m":2,"k":5,"kappa":2,"alpha":1.5}"#, "not both"),
            (r#"{"m":2,"k":5,"warp":9}"#, "unknown key"),
        ];
        for (text, needle) in bad {
            let err = spec_from_json(&json::parse(text).unwrap())
                .expect_err(&format!("{text} must be rejected"));
            assert_eq!(err.kind, ErrorKind::BadRequest, "{text}");
            assert!(err.msg.contains(needle), "{text}: {}", err.msg);
        }
        // minimal spec accepted, defaults applied
        let spec = spec_from_json(&json::parse(r#"{"m":3,"k":7}"#).unwrap()).unwrap();
        assert_eq!((spec.m, spec.k, spec.kappa), (3, 7, 7));
        assert_eq!(spec.algorithm, "lazy");
        // alpha alone works
        let spec = spec_from_json(&json::parse(r#"{"m":3,"k":10,"alpha":2}"#).unwrap()).unwrap();
        assert_eq!(spec.kappa, 20);
    }

    #[test]
    fn reply_lines_round_trip() {
        let ok = ok_line(Some(&Json::num(4.0)), Json::obj([("x", Json::num(1.0))]));
        let result = parse_reply(&ok).unwrap();
        assert_eq!(result.get("x").and_then(|v| v.as_f64()), Some(1.0));

        let err = err_line(None, &WireError::new(ErrorKind::Overloaded, "queue full"));
        let e = parse_reply(&err).unwrap_err();
        assert_eq!(e.kind, ErrorKind::Overloaded);
        assert_eq!(e.msg, "queue full");

        assert!(parse_reply("garbage").is_err());
        assert!(parse_reply("{}").is_err());
    }

    #[test]
    fn query_result_round_trips_value_bits() {
        let run = RunMetrics {
            name: "greedi".into(),
            solution: vec![5, 17, 2],
            value: 0.1234567890123456789,
            oracle_calls: 991,
            rounds: 2,
            ..Default::default()
        };
        let line = ok_line(None, query_result_json(&run, "main", 3, 2, 12.5, 887.25));
        let reply = QueryReply::from_json(&parse_reply(&line).unwrap()).unwrap();
        assert_eq!(reply.solution, run.solution);
        assert_eq!(
            reply.value.to_bits(),
            run.value.to_bits(),
            "f64 must survive the wire bit-for-bit"
        );
        assert_eq!(reply.oracle_calls, 991);
        assert_eq!(reply.rounds, 2);
        assert_eq!(reply.dataset_version, 3);
        assert_eq!(reply.threads_used, 2);
    }

    #[test]
    fn query_result_carries_run_detail_blocks() {
        // built on RunMetrics::to_json: the run's extra detail rides the
        // wire as extra keys old clients simply ignore
        let run = RunMetrics {
            name: "greedi".into(),
            fault: Some(crate::coordinator::metrics::FaultStats {
                policy: "retry".into(),
                multiplicity: 1,
                straggled_machines: vec![2],
                ground_size: 10,
                ..Default::default()
            }),
            ..Default::default()
        };
        let result = query_result_json(&run, "main", 1, 1, 0.0, 1.0);
        assert!(result.get("name").is_none(), "name is re-keyed as protocol");
        assert_eq!(result.get("protocol").and_then(|v| v.as_str()), Some("greedi"));
        assert!(result.get("sim_time").is_some());
        let fault = result.get("fault").expect("fault block rides along");
        assert_eq!(fault.get("policy").and_then(|v| v.as_str()), Some("retry"));
        // and the tolerant client decoder still accepts the richer object
        let line = ok_line(None, result);
        QueryReply::from_json(&parse_reply(&line).unwrap()).unwrap();
    }

    #[test]
    fn error_kinds_round_trip() {
        for k in [
            ErrorKind::BadRequest,
            ErrorKind::UnknownProtocol,
            ErrorKind::UnknownDataset,
            ErrorKind::Overloaded,
            ErrorKind::ShuttingDown,
            ErrorKind::Internal,
            ErrorKind::Unavailable,
        ] {
            assert_eq!(ErrorKind::parse(k.label()), Some(k));
        }
        assert_eq!(ErrorKind::parse("nope"), None);
    }
}
