//! Admission control: a concurrency cap plus a bounded wait queue in front
//! of the query executor.
//!
//! The daemon owns one thread budget (the persistent `util::executor`
//! pool). Letting every connection run a query at full width would
//! oversubscribe it the moment two queries overlap, so admission splits the
//! budget the same way [`RunSpec::oracle_threads`] splits a stage budget
//! across shard tasks: with budget `T` and concurrency cap `c`, each
//! admitted query runs its protocol at `(T / c.clamp(1, T.max(1))).max(1)`
//! threads ([`split_budget`]; a unit test pins the two formulas together).
//! The repo-wide thread-invariance contract (every protocol is bit-identical
//! at any thread count) is what makes this narrowing safe for the served
//! bit-identity guarantee.
//!
//! Flow control is two-level and strictly bounded:
//!
//! * up to `max_concurrency` queries hold a [`Permit`] and run;
//! * up to `queue_depth` more block in [`Admission::admit`] on a condvar;
//! * everything beyond that is **shed immediately** with a typed
//!   [`ErrorKind::Overloaded`] reply — the daemon never buffers unbounded
//!   work, matching the bounded-memory discipline of the `stream` subsystem.
//!
//! An optional per-query **queue-wait deadline** ([`Admission::with_deadline`])
//! bounds how long a waiter may sit in the queue: when it expires the query
//! is shed with a typed `Overloaded` reply instead of blocking a connection
//! handler indefinitely behind a long-running query.
//!
//! [`RunSpec::oracle_threads`]: crate::coordinator::protocol::RunSpec::oracle_threads

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::wire::{ErrorKind, WireError};

/// Per-query thread width for a server budget of `threads` and a
/// concurrency cap of `slots` — the same split [`RunSpec::oracle_threads`]
/// applies to shard tasks, so admitted queries exactly tile the pool.
///
/// [`RunSpec::oracle_threads`]: crate::coordinator::protocol::RunSpec::oracle_threads
pub fn split_budget(threads: usize, slots: usize) -> usize {
    (threads / slots.clamp(1, threads.max(1))).max(1)
}

struct Waitline {
    in_flight: usize,
    waiting: usize,
    peak_in_flight: usize,
    shutting_down: bool,
}

struct Inner {
    max_concurrency: usize,
    queue_depth: usize,
    threads: usize,
    /// Default queue-wait bound applied by [`Admission::admit`]; `None`
    /// waits indefinitely (the pre-deadline behavior).
    deadline: Option<Duration>,
    line: Mutex<Waitline>,
    cv: Condvar,
    admitted: AtomicU64,
    shed: AtomicU64,
    deadline_expired: AtomicU64,
}

/// Counter snapshot for the `stats` reply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionStats {
    pub max_concurrency: usize,
    pub queue_depth: usize,
    pub query_threads: usize,
    pub in_flight: usize,
    pub waiting: usize,
    pub peak_in_flight: usize,
    pub admitted: u64,
    pub shed: u64,
    /// Queries shed because their queue wait exceeded the deadline.
    pub deadline_expired: u64,
}

/// Shared admission gate; clone-cheap via `Arc`.
#[derive(Clone)]
pub struct Admission {
    inner: Arc<Inner>,
}

impl Admission {
    /// `threads` is the server's whole executor budget; `max_concurrency`
    /// queries may run at once (each at [`split_budget`] threads) and
    /// `queue_depth` more may wait.
    pub fn new(threads: usize, max_concurrency: usize, queue_depth: usize) -> Admission {
        let max_concurrency = max_concurrency.max(1);
        Admission {
            inner: Arc::new(Inner {
                max_concurrency,
                queue_depth,
                threads: threads.max(1),
                deadline: None,
                line: Mutex::new(Waitline {
                    in_flight: 0,
                    waiting: 0,
                    peak_in_flight: 0,
                    shutting_down: false,
                }),
                cv: Condvar::new(),
                admitted: AtomicU64::new(0),
                shed: AtomicU64::new(0),
                deadline_expired: AtomicU64::new(0),
            }),
        }
    }

    /// Set the default queue-wait deadline used by [`Admission::admit`]
    /// (`None` = wait indefinitely). Call before sharing the gate — it
    /// configures construction, not live traffic.
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Admission {
        Arc::get_mut(&mut self.inner)
            .expect("set the admission deadline before cloning the gate")
            .deadline = deadline;
        self
    }

    /// Thread width every admitted query runs at.
    pub fn query_threads(&self) -> usize {
        split_budget(self.inner.threads, self.inner.max_concurrency)
    }

    /// Block until a slot frees (bounded by `queue_depth` waiters), or shed.
    /// Uses the gate's default deadline (see [`Admission::with_deadline`]).
    pub fn admit(&self) -> Result<Permit, WireError> {
        self.admit_deadline(self.inner.deadline)
    }

    /// [`Admission::admit`] with an explicit per-query queue-wait bound:
    /// a waiter still queued when `deadline` elapses is shed with a typed
    /// `Overloaded` reply (counted in `deadline_expired`, not `shed`).
    pub fn admit_deadline(&self, deadline: Option<Duration>) -> Result<Permit, WireError> {
        let inner = &self.inner;
        let mut line = inner.line.lock().unwrap();
        if line.shutting_down {
            return Err(WireError::new(ErrorKind::ShuttingDown, "server is shutting down"));
        }
        if line.in_flight >= inner.max_concurrency {
            if line.waiting >= inner.queue_depth {
                inner.shed.fetch_add(1, Ordering::Relaxed);
                return Err(WireError::new(
                    ErrorKind::Overloaded,
                    format!(
                        "admission queue full ({} in flight, {} waiting); retry later",
                        line.in_flight, line.waiting
                    ),
                ));
            }
            let enqueued = Instant::now();
            line.waiting += 1;
            while line.in_flight >= inner.max_concurrency && !line.shutting_down {
                match deadline {
                    None => line = inner.cv.wait(line).unwrap(),
                    Some(d) => {
                        let Some(left) = d.checked_sub(enqueued.elapsed()) else {
                            line.waiting -= 1;
                            drop(line);
                            inner.deadline_expired.fetch_add(1, Ordering::Relaxed);
                            return Err(WireError::new(
                                ErrorKind::Overloaded,
                                format!(
                                    "queue-wait deadline expired after {:.0?}; retry later",
                                    d
                                ),
                            ));
                        };
                        line = inner.cv.wait_timeout(line, left).unwrap().0;
                    }
                }
            }
            line.waiting -= 1;
            if line.shutting_down {
                // another waiter may also be eligible to observe the flag
                inner.cv.notify_one();
                return Err(WireError::new(ErrorKind::ShuttingDown, "server is shutting down"));
            }
        }
        line.in_flight += 1;
        line.peak_in_flight = line.peak_in_flight.max(line.in_flight);
        inner.admitted.fetch_add(1, Ordering::Relaxed);
        drop(line);
        Ok(Permit { inner: Arc::clone(inner) })
    }

    /// Fail queued waiters (and all future `admit`s) with `ShuttingDown`.
    pub fn shutdown(&self) {
        let mut line = self.inner.line.lock().unwrap();
        line.shutting_down = true;
        self.inner.cv.notify_all();
    }

    pub fn stats(&self) -> AdmissionStats {
        let line = self.inner.line.lock().unwrap();
        AdmissionStats {
            max_concurrency: self.inner.max_concurrency,
            queue_depth: self.inner.queue_depth,
            query_threads: self.query_threads(),
            in_flight: line.in_flight,
            waiting: line.waiting,
            peak_in_flight: line.peak_in_flight,
            admitted: self.inner.admitted.load(Ordering::Relaxed),
            shed: self.inner.shed.load(Ordering::Relaxed),
            deadline_expired: self.inner.deadline_expired.load(Ordering::Relaxed),
        }
    }
}

/// RAII admission slot: holding one entitles the query to
/// [`Permit::threads`] pool threads; dropping it wakes the next waiter.
pub struct Permit {
    inner: Arc<Inner>,
}

impl Permit {
    pub fn threads(&self) -> usize {
        split_budget(self.inner.threads, self.inner.max_concurrency)
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut line = self.inner.line.lock().unwrap();
        line.in_flight -= 1;
        drop(line);
        self.inner.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::RunSpec;
    use std::time::Duration;

    #[test]
    fn split_matches_oracle_threads_model() {
        for threads in [1usize, 2, 3, 4, 7, 8, 16] {
            for slots in [1usize, 2, 3, 5, 8, 32] {
                let spec = RunSpec::new(4, 5).threads(threads);
                assert_eq!(
                    split_budget(threads, slots),
                    spec.oracle_threads(slots),
                    "threads={threads} slots={slots}"
                );
            }
        }
    }

    #[test]
    fn budget_never_oversubscribed() {
        for threads in [1usize, 2, 4, 8, 16] {
            for slots in [1usize, 2, 3, 4, 8] {
                let per = split_budget(threads, slots);
                assert!(per >= 1);
                if slots <= threads {
                    assert!(per * slots <= threads, "threads={threads} slots={slots} per={per}");
                }
            }
        }
    }

    #[test]
    fn admits_to_cap_then_sheds_past_queue() {
        let adm = Admission::new(8, 2, 0);
        let p1 = adm.admit().unwrap();
        let p2 = adm.admit().unwrap();
        let err = adm.admit().unwrap_err();
        assert_eq!(err.kind, ErrorKind::Overloaded);
        let s = adm.stats();
        assert_eq!((s.in_flight, s.peak_in_flight, s.admitted, s.shed), (2, 2, 2, 1));
        assert_eq!(s.query_threads, 4);
        drop(p1);
        let _p3 = adm.admit().unwrap();
        drop(p2);
        assert_eq!(adm.stats().in_flight, 1);
        assert_eq!(adm.stats().peak_in_flight, 2);
    }

    #[test]
    fn queued_waiter_runs_after_release() {
        let adm = Admission::new(4, 1, 4);
        let permit = adm.admit().unwrap();
        let adm2 = adm.clone();
        let waiter = std::thread::spawn(move || adm2.admit().map(|p| p.threads()));
        // let the waiter reach the condvar
        while adm.stats().waiting == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        drop(permit);
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(got, 4, "solo query gets the whole budget");
        assert_eq!(adm.stats().admitted, 2);
        assert_eq!(adm.stats().shed, 0);
    }

    #[test]
    fn deadline_expiry_sheds_with_typed_overloaded() {
        let adm = Admission::new(4, 1, 4).with_deadline(Some(Duration::from_millis(20)));
        let _permit = adm.admit().unwrap();
        // slot held, queue has room => this waiter parks, then times out
        let err = adm.admit().unwrap_err();
        assert_eq!(err.kind, ErrorKind::Overloaded);
        assert!(err.msg.contains("deadline"), "unexpected message {:?}", err.msg);
        let s = adm.stats();
        assert_eq!(s.waiting, 0, "expired waiter must leave the queue");
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.shed, 0, "deadline expiry counted separately from queue-full sheds");
        assert_eq!(s.in_flight, 1);
    }

    #[test]
    fn deadline_irrelevant_when_slot_free_and_explicit_override_wins() {
        let adm = Admission::new(4, 1, 1).with_deadline(Some(Duration::from_millis(1)));
        // free slot: admitted immediately, deadline never consulted
        let permit = adm.admit_deadline(Some(Duration::ZERO)).unwrap();
        // held slot + zero explicit deadline: immediate typed shed
        let err = adm.admit_deadline(Some(Duration::ZERO)).unwrap_err();
        assert_eq!(err.kind, ErrorKind::Overloaded);
        assert_eq!(adm.stats().deadline_expired, 1);
        drop(permit);
        // released: the default deadline only bounds *waiting*, not running
        let _p = adm.admit().unwrap();
        assert_eq!(adm.stats().admitted, 2);
    }

    #[test]
    fn shutdown_fails_waiters_and_future_admits() {
        let adm = Admission::new(4, 1, 4);
        let permit = adm.admit().unwrap();
        let adm2 = adm.clone();
        let waiter = std::thread::spawn(move || adm2.admit().err().map(|e| e.kind));
        while adm.stats().waiting == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        adm.shutdown();
        assert_eq!(waiter.join().unwrap(), Some(ErrorKind::ShuttingDown));
        assert_eq!(adm.admit().unwrap_err().kind, ErrorKind::ShuttingDown);
        drop(permit);
    }
}
