//! The resident daemon: TCP accept loop, per-connection NDJSON dispatch,
//! and the [`ServeSpec`] it boots from.
//!
//! One `Server` owns one [`WarmState`] registry, one [`Admission`] gate and
//! one [`ServeMetrics`] recorder, shared across a thread-per-connection
//! accept loop. Queries run **on the connection thread** under an admission
//! [`Permit`](super::admission::Permit) that fixes their executor width, so
//! the persistent `util::executor` pool is tiled, never oversubscribed.
//! Protocol panics are caught and returned as typed
//! [`ErrorKind::Internal`] replies instead of killing the connection.
//!
//! [`Server::with_parts`] exposes the composed pieces for tests: handing
//! the server a pre-built [`Admission`] lets `tests/integration_serve.rs`
//! hold a permit itself and drive the shed path deterministically.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::protocol;
use crate::util::json::Json;
use crate::util::toml;
use crate::util::trace;

use super::admission::Admission;
use super::metrics::{ServeMetrics, DEFAULT_RING};
use super::state::WarmState;
use super::wire::{self, ErrorKind, QueryRequest, Request, WireError};

/// Boot parameters for the daemon — the `[serve]` TOML section.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Listen address (`serve.addr`); `"host:0"` binds an ephemeral port.
    pub addr: String,
    /// Queries allowed to run at once (`serve.max_concurrency`).
    pub max_concurrency: usize,
    /// Queries allowed to wait for a slot (`serve.queue_depth`); beyond
    /// this, shed with [`ErrorKind::Overloaded`]. 0 = shed immediately.
    pub queue_depth: usize,
    /// Whole-server executor budget (`serve.threads`), split across
    /// admitted queries by the `oracle_threads` model.
    pub threads: usize,
    /// Dataset served when a request names none (`serve.dataset`).
    pub dataset: String,
    /// Latency ring-buffer capacity (`serve.ring`).
    pub ring: usize,
    /// Queue-wait deadline in milliseconds (`serve.deadline_ms`); a query
    /// still waiting for a slot after this long is shed with a typed
    /// `overloaded` reply. 0 = wait indefinitely (the default).
    pub deadline_ms: u64,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            addr: "127.0.0.1:7199".into(),
            max_concurrency: 4,
            queue_depth: 16,
            threads: 4,
            dataset: "demo".into(),
            ring: DEFAULT_RING,
            deadline_ms: 0,
        }
    }
}

impl ServeSpec {
    /// Parse the `[serve]` section out of a TOML document. Non-`serve.*`
    /// keys are ignored (they belong to [`ExperimentConfig`]), unknown
    /// `serve.*` keys are rejected — same discipline as the experiment
    /// config, so a preset file can carry both sections.
    ///
    /// [`ExperimentConfig`]: crate::config::ExperimentConfig
    pub fn from_toml(text: &str) -> Result<ServeSpec, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &toml::Document) -> Result<ServeSpec, String> {
        let mut spec = ServeSpec::default();
        for (key, value) in &doc.entries {
            let Some(field) = key.strip_prefix("serve.") else {
                continue;
            };
            match field {
                "addr" => spec.addr = value.as_str().ok_or("serve.addr: string")?.into(),
                "max_concurrency" => {
                    spec.max_concurrency =
                        value.as_usize().ok_or("serve.max_concurrency: int")?
                }
                "queue_depth" => {
                    spec.queue_depth = value.as_usize().ok_or("serve.queue_depth: int")?
                }
                "threads" => spec.threads = value.as_usize().ok_or("serve.threads: int")?,
                "dataset" => {
                    spec.dataset = value.as_str().ok_or("serve.dataset: string")?.into()
                }
                "ring" => spec.ring = value.as_usize().ok_or("serve.ring: int")?,
                "deadline_ms" => {
                    spec.deadline_ms =
                        value.as_usize().ok_or("serve.deadline_ms: int")? as u64
                }
                other => return Err(format!("unknown serve key \"serve.{other}\"")),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.addr.is_empty() || !self.addr.contains(':') {
            return Err(format!("serve.addr must be host:port, got {:?}", self.addr));
        }
        if self.max_concurrency == 0 {
            return Err("serve.max_concurrency must be > 0".into());
        }
        if self.threads == 0 {
            return Err("serve.threads must be > 0".into());
        }
        if self.dataset.is_empty() {
            return Err("serve.dataset must be non-empty".into());
        }
        if self.ring == 0 {
            return Err("serve.ring must be > 0".into());
        }
        Ok(())
    }
}

struct Shared {
    state: Arc<WarmState>,
    admission: Admission,
    metrics: Arc<ServeMetrics>,
    default_dataset: String,
    addr: SocketAddr,
    started: Instant,
    stop: AtomicBool,
}

/// A running daemon. Dropping it stops the accept loop.
pub struct Server {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving with freshly built admission/metrics.
    pub fn start(spec: &ServeSpec, state: Arc<WarmState>) -> Result<Server, String> {
        spec.validate()?;
        let deadline =
            (spec.deadline_ms > 0).then(|| std::time::Duration::from_millis(spec.deadline_ms));
        let admission = Admission::new(spec.threads, spec.max_concurrency, spec.queue_depth)
            .with_deadline(deadline);
        let metrics = Arc::new(ServeMetrics::new(spec.ring));
        Server::with_parts(spec, state, admission, metrics)
    }

    /// Start with caller-supplied parts (tests hold a [`Permit`] on the
    /// same [`Admission`] to exercise shedding deterministically).
    ///
    /// [`Permit`]: super::admission::Permit
    pub fn with_parts(
        spec: &ServeSpec,
        state: Arc<WarmState>,
        admission: Admission,
        metrics: Arc<ServeMetrics>,
    ) -> Result<Server, String> {
        let listener =
            TcpListener::bind(&spec.addr).map_err(|e| format!("bind {}: {e}", spec.addr))?;
        let addr = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        let shared = Arc::new(Shared {
            state,
            admission,
            metrics,
            default_dataset: spec.dataset.clone(),
            addr,
            started: Instant::now(),
            stop: AtomicBool::new(false),
        });
        let sh = Arc::clone(&shared);
        let accept = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if sh.stop.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    let sh2 = Arc::clone(&sh);
                    std::thread::spawn(move || handle_conn(stream, sh2));
                }
            }
        });
        Ok(Server { shared, accept: Some(accept) })
    }

    /// Bound address (resolves the port when the spec asked for `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn admission(&self) -> Admission {
        self.shared.admission.clone()
    }

    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.shared.metrics)
    }

    pub fn state(&self) -> Arc<WarmState> {
        Arc::clone(&self.shared.state)
    }

    /// Block until the accept loop exits — i.e. until some client sends a
    /// wire `shutdown` (what `greedi serve` parks on).
    pub fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, fail queued admissions, join the accept loop.
    /// Idempotent; also runs on drop and after a wire `shutdown`.
    pub fn stop(&mut self) {
        if !self.shared.stop.swap(true, Ordering::SeqCst) {
            self.shared.admission.shutdown();
        }
        // unblock the accept loop if it is still parked in accept()
        let _ = TcpStream::connect(self.shared.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break, // client went away
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (reply, shutdown) = handle_line(&shared, trimmed);
        let sent = writer
            .write_all(reply.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush());
        if sent.is_err() {
            break;
        }
        if shutdown {
            shared.stop.store(true, Ordering::SeqCst);
            shared.admission.shutdown();
            // wake the accept loop so it observes the stop flag
            let _ = TcpStream::connect(shared.addr);
            break;
        }
    }
}

/// Dispatch one request line to one reply line. The bool asks the caller
/// to begin server shutdown after the reply is flushed.
fn handle_line(shared: &Shared, line: &str) -> (String, bool) {
    let (id, req) = wire::parse_request(line);
    let req = match req {
        Ok(r) => r,
        Err(e) => {
            shared.metrics.record_error();
            return (wire::err_line(id.as_ref(), &e), false);
        }
    };
    let id = id.as_ref();
    crate::trace_counter!("serve.requests").incr();
    match req {
        Request::Ping => {
            crate::trace_counter!("serve.op.ping").incr();
            let result = Json::obj([
                ("op", Json::str("pong")),
                ("uptime_s", Json::num(shared.started.elapsed().as_secs_f64())),
                (
                    "protocols",
                    Json::Arr(protocol::NAMES.iter().map(|n| Json::str(*n)).collect()),
                ),
            ]);
            (wire::ok_line(id, result), false)
        }
        Request::Stats => {
            crate::trace_counter!("serve.op.stats").incr();
            (wire::ok_line(id, stats_json(shared)), false)
        }
        Request::Datasets => {
            crate::trace_counter!("serve.op.datasets").incr();
            let rows = shared
                .state
                .list()
                .into_iter()
                .map(|d| {
                    Json::obj([
                        ("name", Json::str(d.name)),
                        ("n", Json::num(d.n as f64)),
                        ("d", Json::num(d.d as f64)),
                        ("version", Json::num(d.version as f64)),
                        ("streaming", Json::Bool(d.streaming)),
                        ("warm", Json::Bool(d.warm)),
                    ])
                })
                .collect();
            (wire::ok_line(id, Json::obj([("datasets", Json::Arr(rows))])), false)
        }
        Request::Warm { dataset } => {
            crate::trace_counter!("serve.op.warm").incr();
            let name = dataset.as_deref().unwrap_or(&shared.default_dataset);
            match shared.state.snapshot(name) {
                None => (err_reply(shared, id, unknown_dataset(name)), false),
                Some(snap) => {
                    let (n, was_warm) = snap.warm(shared.admission.query_threads());
                    let result = Json::obj([
                        ("dataset", Json::str(name)),
                        ("version", Json::num(snap.version as f64)),
                        ("n", Json::num(n as f64)),
                        ("was_warm", Json::Bool(was_warm)),
                    ]);
                    (wire::ok_line(id, result), false)
                }
            }
        }
        Request::Advance { dataset, count } => {
            crate::trace_counter!("serve.op.advance").incr();
            let name = dataset.as_deref().unwrap_or(&shared.default_dataset);
            if shared.state.snapshot(name).is_none() {
                return (err_reply(shared, id, unknown_dataset(name)), false);
            }
            match shared.state.advance(name, count) {
                Err(msg) => (err_reply(shared, id, WireError::bad(msg)), false),
                Ok((added, live, version)) => {
                    let result = Json::obj([
                        ("dataset", Json::str(name)),
                        ("added", Json::num(added as f64)),
                        ("live", Json::num(live as f64)),
                        ("version", Json::num(version as f64)),
                    ]);
                    (wire::ok_line(id, result), false)
                }
            }
        }
        Request::Query(q) => {
            crate::trace_counter!("serve.op.query").incr();
            (run_query(shared, *q, id), false)
        }
        Request::Shutdown => {
            crate::trace_counter!("serve.op.shutdown").incr();
            (wire::ok_line(id, Json::obj([("op", Json::str("shutdown"))])), true)
        }
    }
}

fn unknown_dataset(name: &str) -> WireError {
    WireError::new(ErrorKind::UnknownDataset, format!("unknown dataset {name:?}"))
}

fn err_reply(shared: &Shared, id: Option<&Json>, e: WireError) -> String {
    shared.metrics.record_error();
    wire::err_line(id, &e)
}

fn stats_json(shared: &Shared) -> Json {
    let a = shared.admission.stats();
    let (hits, misses) = shared.state.cache_counts();
    Json::obj([
        ("uptime_s", Json::num(shared.started.elapsed().as_secs_f64())),
        (
            "admission",
            Json::obj([
                ("max_concurrency", Json::num(a.max_concurrency as f64)),
                ("queue_depth", Json::num(a.queue_depth as f64)),
                ("query_threads", Json::num(a.query_threads as f64)),
                ("in_flight", Json::num(a.in_flight as f64)),
                ("waiting", Json::num(a.waiting as f64)),
                ("peak_in_flight", Json::num(a.peak_in_flight as f64)),
                ("admitted", Json::num(a.admitted as f64)),
                ("shed", Json::num(a.shed as f64)),
                ("deadline_expired", Json::num(a.deadline_expired as f64)),
            ]),
        ),
        (
            "cache",
            Json::obj([
                ("singleton_hits", Json::num(hits as f64)),
                ("singleton_misses", Json::num(misses as f64)),
            ]),
        ),
        ("latency", shared.metrics.to_json()),
        ("trace", trace::metrics_snapshot()),
    ])
}

fn run_query(shared: &Shared, q: QueryRequest, id: Option<&Json>) -> String {
    let _query_span = trace::span_with("serve.query", || {
        vec![("protocol", q.protocol.as_str().into())]
    });
    let t0 = Instant::now();
    let Some(proto) = protocol::by_name(&q.protocol) else {
        return err_reply(
            shared,
            id,
            WireError::new(
                ErrorKind::UnknownProtocol,
                format!(
                    "unknown protocol {:?} — known: {}",
                    q.protocol,
                    protocol::NAMES.join(", ")
                ),
            ),
        );
    };
    let name = q.dataset.as_deref().unwrap_or(&shared.default_dataset).to_string();
    let Some(snap) = shared.state.snapshot(&name) else {
        return err_reply(shared, id, unknown_dataset(&name));
    };
    let permit = match shared.admission.admit() {
        Ok(p) => p,
        Err(e) => return err_reply(shared, id, e),
    };
    let queued_us = t0.elapsed().as_secs_f64() * 1e6;
    // Narrow the query to its admission share of the pool. Protocol output
    // is thread-invariant (repo-wide contract), so this never changes the
    // solution — only how much of the executor the query may occupy.
    let threads_used = permit.threads();
    let spec = q.spec.threads(threads_used);
    let problem = snap.problem();
    let run = catch_unwind(AssertUnwindSafe(|| proto.run(&problem, &spec)));
    drop(permit);
    match run {
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "protocol panicked".into());
            err_reply(
                shared,
                id,
                WireError::new(ErrorKind::Internal, format!("protocol {:?}: {msg}", q.protocol)),
            )
        }
        Ok(run) => {
            let latency_us = t0.elapsed().as_secs_f64() * 1e6;
            shared.metrics.record_query(queued_us, latency_us);
            trace::histogram("serve.latency_us").record(latency_us as u64);
            wire::ok_line(
                id,
                wire::query_result_json(
                    &run,
                    &name,
                    snap.version,
                    threads_used,
                    queued_us,
                    latency_us,
                ),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_defaults_without_serve_section() {
        let spec = ServeSpec::from_toml("n = 500\nthreads = 2\n").unwrap();
        assert_eq!(spec, ServeSpec::default(), "non-serve keys are not ours to parse");
    }

    #[test]
    fn spec_parses_full_section() {
        let spec = ServeSpec::from_toml(
            r#"
            protocol = "greedi"

            [serve]
            addr = "0.0.0.0:9000"
            max_concurrency = 8
            queue_depth = 32
            threads = 16
            dataset = "tiny"
            ring = 512
            deadline_ms = 250
            "#,
        )
        .unwrap();
        assert_eq!(spec.addr, "0.0.0.0:9000");
        assert_eq!(spec.max_concurrency, 8);
        assert_eq!(spec.queue_depth, 32);
        assert_eq!(spec.threads, 16);
        assert_eq!(spec.dataset, "tiny");
        assert_eq!(spec.ring, 512);
        assert_eq!(spec.deadline_ms, 250);
    }

    #[test]
    fn deadline_zero_means_wait_forever() {
        let spec = ServeSpec::from_toml("[serve]\ndeadline_ms = 0\n").unwrap();
        assert_eq!(spec.deadline_ms, 0);
        spec.validate().unwrap();
    }

    #[test]
    fn spec_rejects_unknown_serve_key() {
        let err = ServeSpec::from_toml("[serve]\nports = 3\n").unwrap_err();
        assert!(err.contains("serve.ports"), "{err}");
    }

    #[test]
    fn spec_rejects_bad_types() {
        assert!(ServeSpec::from_toml("[serve]\naddr = 3\n").is_err());
        assert!(ServeSpec::from_toml("[serve]\nmax_concurrency = \"two\"\n").is_err());
        assert!(ServeSpec::from_toml("[serve]\nqueue_depth = \"deep\"\n").is_err());
    }

    #[test]
    fn spec_rejects_invalid_values() {
        let err = ServeSpec::from_toml("[serve]\nmax_concurrency = 0\n").unwrap_err();
        assert!(err.contains("max_concurrency"), "{err}");
        let err = ServeSpec::from_toml("[serve]\nthreads = 0\n").unwrap_err();
        assert!(err.contains("threads"), "{err}");
        let err = ServeSpec::from_toml("[serve]\naddr = \"nocolon\"\n").unwrap_err();
        assert!(err.contains("host:port"), "{err}");
        let err = ServeSpec::from_toml("[serve]\ndataset = \"\"\n").unwrap_err();
        assert!(err.contains("dataset"), "{err}");
        let err = ServeSpec::from_toml("[serve]\nring = 0\n").unwrap_err();
        assert!(err.contains("ring"), "{err}");
    }

    #[test]
    fn queue_depth_zero_is_valid_shed_immediately() {
        let spec = ServeSpec::from_toml("[serve]\nqueue_depth = 0\n").unwrap();
        assert_eq!(spec.queue_depth, 0);
        spec.validate().unwrap();
    }
}
