//! `cargo bench` target: closed-loop load on the always-on selection
//! daemon. Boots a real `serve::Server` on an ephemeral port, measures
//! single-query round-trip latency (cold vs warm singleton cache), then
//! drives a closed loop of concurrent clients through admission control
//! and reports qps + p50/p99 from the daemon's own metrics surface.
//!
//! `GREEDI_BENCH_FAST=1` shrinks sizes for CI;
//! `GREEDI_BENCH_JSON=BENCH_serve.json` dumps `op -> number` — the Bencher
//! ns/iter rows merged (via the `util::json` reader+writer round-trip)
//! with `serve: qps` / `serve: p50 us` / `serve: p99 us`, so serving
//! throughput joins the per-op delta table in CI.

use std::sync::Arc;
use std::time::Instant;

use greedi::coordinator::protocol::RunSpec;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::serve::{Client, ServeSpec, Server, WarmState};
use greedi::util::bench::{black_box, Bencher};
use greedi::util::json::{self, Json};

fn main() {
    let fast = std::env::var("GREEDI_BENCH_FAST").ok().as_deref() == Some("1");
    let (n, clients, per_client) = if fast { (800, 4, 5) } else { (4_000, 8, 20) };
    let (threads, conc) = (8, 4);
    let mut b = Bencher::new(1, if fast { 3 } else { 10 });

    println!("== serve benchmarks (n={n}, budget {threads} threads / {conc} slots) ==\n");

    let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), 1));
    let state = Arc::new(WarmState::new());
    state.register("demo", Arc::clone(&data));
    let mut spec = ServeSpec::default();
    spec.addr = "127.0.0.1:0".into();
    spec.threads = threads;
    spec.max_concurrency = conc;
    spec.queue_depth = clients * per_client;
    let server = Server::start(&spec, state).expect("bind ephemeral port");
    let addr = server.addr();
    let qspec = RunSpec::new(4, 8).seed(1);

    // ---- 1. single-query round-trip, cold vs warm singleton cache --------
    let mut probe = Client::connect(addr).expect("connect");
    b.bench("serve: query round-trip (cold cache)", || {
        black_box(probe.query("stream_greedi", None, &qspec).expect("query").value)
    });
    probe.warm(None).expect("warm");
    b.bench("serve: query round-trip (warm cache)", || {
        black_box(probe.query("stream_greedi", None, &qspec).expect("query").value)
    });
    b.bench("serve: ping round-trip", || black_box(probe.ping().expect("ping").dump().len()));

    // ---- 2. closed-loop concurrent load through admission -----------------
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|_| {
            let qspec = qspec.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                let mut checksum = 0.0;
                for _ in 0..per_client {
                    checksum += c.query("greedi", None, &qspec).expect("query").value;
                }
                checksum
            })
        })
        .collect();
    let mut checksum = 0.0;
    for w in workers {
        checksum += w.join().expect("client thread");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    black_box(checksum);
    let total = (clients * per_client) as f64;
    let loop_qps = total / wall_s;

    // the daemon's own latency surface (what the `stats` op serves)
    let m = server.metrics().snapshot();
    println!("\n== closed loop: {clients} clients x {per_client} queries ==");
    println!("  wall = {wall_s:.3}s -> {loop_qps:.1} qps (daemon-side qps {:.1})", m.qps);
    println!(
        "  latency p50 = {:.0}us  p99 = {:.0}us  max = {:.0}us (n={})",
        m.latency.p50_us, m.latency.p99_us, m.latency.max_us, m.latency.count
    );
    println!(
        "  admission queue p50 = {:.0}us  p99 = {:.0}us",
        m.queued.p50_us, m.queued.p99_us
    );

    // ---- 3. perf trail: Bencher rows + serving throughput, one flat file --
    if let Ok(path) = std::env::var("GREEDI_BENCH_JSON") {
        if !path.is_empty() {
            let mut doc = json::parse(&b.to_json()).expect("bencher json");
            if let Json::Obj(map) = &mut doc {
                map.insert("serve: qps".into(), Json::num(loop_qps));
                map.insert("serve: p50 us".into(), Json::num(m.latency.p50_us));
                map.insert("serve: p99 us".into(), Json::num(m.latency.p99_us));
                map.insert("serve: queued p99 us".into(), Json::num(m.queued.p99_us));
            }
            match std::fs::write(&path, json::write(&doc) + "\n") {
                Ok(()) => println!("(wrote bench JSON to {path})"),
                Err(e) => eprintln!("warning: could not write bench JSON to {path}: {e}"),
            }
        }
    }
}
