//! `cargo bench` target: micro/meso benchmarks of the hot paths that the
//! §Perf optimization pass iterates on (see EXPERIMENTS.md §Perf):
//!
//!   1. facility-location marginal gains — scalar loop vs cached-curmin
//!      state vs the XLA batched artifact;
//!   2. plain vs lazy vs stochastic greedy oracle-call economics;
//!   3. incremental Cholesky vs dense log-det for info-gain;
//!   4. the two-round protocol end-to-end.

use std::ops::Range;
use std::sync::{Arc, Mutex};

use greedi::algorithms::{greedy::Greedy, lazy::LazyGreedy, stochastic::StochasticGreedy, Maximizer};
use greedi::constraints::cardinality::Cardinality;
use greedi::coordinator::greedi::{centralized, Greedi};
use greedi::coordinator::protocol::{Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, parkinsons_like, SynthConfig};
use greedi::linalg::{IncrementalCholesky, Matrix};
use greedi::objective::dpp::DppLogDet;
use greedi::objective::facility::{
    kernel_name, kernel_sq_dist, kernel_sq_dist_scalar, window_shards, FacilityLocation,
};
use greedi::objective::infogain::InfoGain;
use greedi::objective::SubmodularFn;
use greedi::util::bench::{black_box, Bencher};
use greedi::util::executor::{parallel_map, shard_ranges};
use greedi::util::rng::Rng;

/// The pre-PR serial scalar gain path, frozen here as the perf baseline the
/// window-sharded engine is measured against: one running f32 accumulator
/// per point (no lanes), full-window stream per candidate, no sharding.
/// TIMING reference only — it returns unnormalized sums and its `curmin`
/// below is seeded via f64 `sqdist`, so its values differ from the engine's
/// in scale and low-order bits; don't cross-validate numbers against it.
fn serial_scalar_gains(
    packed: &[f32],
    d: usize,
    curmin: &[f64],
    erows: &[&[f32]],
) -> Vec<f64> {
    erows
        .iter()
        .map(|&erow| {
            let mut sum = 0.0f64;
            for (idx, vrow) in packed.chunks_exact(d).enumerate() {
                let mut d2 = 0.0f32;
                for t in 0..d {
                    let diff = vrow[t] - erow[t];
                    d2 += diff * diff;
                }
                let gain = curmin[idx] - d2 as f64;
                if gain > 0.0 {
                    sum += gain;
                }
            }
            sum
        })
        .collect()
}

/// The pre-PR-4 fan-out model, frozen as a timing baseline: scoped OS
/// threads spawned per batch (what `util::threadpool::parallel_map` did
/// before the persistent executor). The ~10 µs-per-batch launch cost this
/// pays is exactly what the executor's small-window rows measure against.
fn scoped_spawn_map<T: Send, R: Send, F: Fn(usize, T) -> R + Sync>(
    items: Vec<T>,
    workers: usize,
    f: F,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<R>>> = results.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.max(1).min(n) {
            scope.spawn(|| loop {
                let next = { work.lock().unwrap().next() };
                let Some((idx, item)) = next else { break };
                **slots[idx].lock().unwrap() = Some(f(idx, item));
            });
        }
    });
    drop(slots);
    results.into_iter().map(|r| r.expect("task did not complete")).collect()
}

fn main() {
    let fast = std::env::var("GREEDI_BENCH_FAST").ok().as_deref() == Some("1");
    let (n, k) = if fast { (800, 10) } else { (4_000, 32) };
    let mut b = Bencher::new(1, if fast { 2 } else { 5 });

    // The gains section runs on a FIXED 4096-point window even in fast mode:
    // shard_count caps window shards at |W|/256, so a smaller fast-mode
    // window would starve the 4t/8t rows of parallelism and the CI perf
    // trail would chart shard starvation instead of thread scaling.
    let n_gain = 4_096usize;
    println!("== hot-path benchmarks (n={n}, n_gain={n_gain}, k={k}) ==\n");

    // ---- 1. facility gains ------------------------------------------------
    let ds_gain = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n_gain, 16), 1));
    let fac_gain = FacilityLocation::from_dataset(&ds_gain);
    let cands: Vec<usize> = (0..64).collect();
    {
        // Reconstruct the state {100} outside the objective so the pre-PR
        // scalar loop streams a buffer of identical shape and occupancy.
        let d = ds_gain.d;
        let packed = ds_gain.xs.clone();
        let mut curmin: Vec<f64> = (0..n_gain)
            .map(|v| ds_gain.row(v).iter().map(|&x| (x as f64) * (x as f64)).sum())
            .collect();
        for v in 0..n_gain {
            let d2 = ds_gain.sqdist(100, v);
            if d2 < curmin[v] {
                curmin[v] = d2;
            }
        }
        let erows: Vec<&[f32]> = cands.iter().map(|&c| ds_gain.row(c)).collect();
        b.bench("facility: 64 gains, serial scalar (pre-PR)", || {
            black_box(serial_scalar_gains(&packed, d, &curmin, &erows))
        });
    }
    {
        let mut st = fac_gain.state();
        st.push(100);
        b.bench("facility: 64 gains, cached-curmin state", || {
            black_box(st.batch_gains(&cands))
        });
        for threads in [1usize, 2, 4, 8] {
            b.bench(
                &format!("facility: 64 gains, sharded engine ({threads}t)"),
                || black_box(st.par_batch_gains(&cands, threads)),
            );
        }
    }
    b.bench("facility: 64 gains, naive eval() diffs", || {
        let base = fac_gain.eval(&[100]);
        let mut out = Vec::with_capacity(64);
        for &c in &cands {
            out.push(fac_gain.eval(&[100, c]) - base);
        }
        black_box(out)
    });

    // ---- 1b. small-window sweep: executor vs per-batch scoped spawn ------
    // |W| ∈ {1k, 10k, 100k} × threads {1, 2, 4, 8} on narrow 16-candidate
    // batches — exactly the shape where the old per-batch thread launch
    // dominated and bounded the speedup. "scoped-spawn" rows run the same
    // shard boundaries + shard-ordered scalar reduction through per-batch
    // `thread::scope` fan-out (the frozen pre-PR engine shape); "executor"
    // rows are the live `par_batch_gains` path on the persistent pool.
    println!("\n(facility distance kernel: {})\n", kernel_name());
    let cands16: Vec<usize> = (0..16).collect();
    for &w in &[1_000usize, 10_000, 100_000] {
        let ds_w = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(w, 16), 7));
        let fw = FacilityLocation::from_dataset(&ds_w);
        let mut st = fw.state();
        st.push(0);
        // frozen-baseline state {0}, same buffer shape/occupancy as `st`
        let d = ds_w.d;
        let packed = ds_w.xs.clone();
        let mut curmin: Vec<f64> = (0..w)
            .map(|v| ds_w.row(v).iter().map(|&x| (x as f64) * (x as f64)).sum())
            .collect();
        for v in 0..w {
            let d2 = ds_w.sqdist(0, v);
            if d2 < curmin[v] {
                curmin[v] = d2;
            }
        }
        let erows: Vec<&[f32]> = cands16.iter().map(|&c| ds_w.row(c)).collect();
        // mirror the engine's window shard rule exactly (its boundaries are
        // shape-only, so the frozen baseline shards identically)
        let shards = shard_ranges(w, window_shards(w));
        for &t in &[1usize, 2, 4, 8] {
            b.bench(&format!("smallwin |W|={w}: 16 gains, scoped-spawn ({t}t)"), || {
                let partials = scoped_spawn_map(shards.clone(), t, |_, r: Range<usize>| {
                    serial_scalar_gains(
                        &packed[r.start * d..r.end * d],
                        d,
                        &curmin[r.start..r.end],
                        &erows,
                    )
                });
                let mut out = vec![0.0f64; erows.len()];
                for p in &partials {
                    for (acc, v) in out.iter_mut().zip(p) {
                        *acc += v;
                    }
                }
                black_box(out)
            });
            b.bench(&format!("smallwin |W|={w}: 16 gains, executor ({t}t)"), || {
                black_box(st.par_batch_gains(&cands16, t))
            });
        }
    }

    // Pure launch-overhead isolation: trivial tasks, so the row measures
    // fan-out machinery only (thread spawn+join vs deque submit+wake).
    for &t in &[2usize, 4, 8] {
        b.bench(&format!("spawn overhead: scoped thread::scope ({t} tasks)"), || {
            black_box(scoped_spawn_map((0..t).collect::<Vec<usize>>(), t, |_, x| x))
        });
        b.bench(&format!("spawn overhead: persistent executor ({t} tasks)"), || {
            black_box(parallel_map((0..t).collect::<Vec<usize>>(), t, |_, x| x))
        });
    }

    // ---- 1c. SIMD vs scalar distance kernel -------------------------------
    let ka: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
    let kb: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11).cos()).collect();
    b.bench("kernel: sq_dist dispatched, d=64 x 10k", || {
        let mut acc = 0.0f32;
        for _ in 0..10_000 {
            acc += kernel_sq_dist(black_box(&ka), black_box(&kb));
        }
        black_box(acc)
    });
    b.bench("kernel: sq_dist scalar, d=64 x 10k", || {
        let mut acc = 0.0f32;
        for _ in 0..10_000 {
            acc += kernel_sq_dist_scalar(black_box(&ka), black_box(&kb));
        }
        black_box(acc)
    });

    // ---- 1d. engine-path rows: the newly parallel Cholesky objectives ----
    // infogain/dpp went from serial element-at-a-time pricing to
    // candidate-sharded engine batches in the gain-engine refactor; these
    // rows give the next perf PR a thread-scaling baseline in the JSON
    // trail. k = 24 committed elements → every candidate pays an O(k²)
    // forward solve (per-shard probe columns / Schur complements).
    {
        let pk_n = if fast { 600 } else { 2_000 };
        let pk = Arc::new(parkinsons_like(pk_n, 22, 4));
        let chol_cands: Vec<usize> = (0..64).map(|i| (i * 13) % pk_n).collect();
        let ig = InfoGain::paper_params(&pk);
        let mut ig_st = ig.state();
        for i in 0..24 {
            ig_st.push((i * 17 + 64) % pk_n);
        }
        for &t in &[1usize, 4, 8] {
            b.bench(&format!("infogain: 64 gains, engine ({t}t)"), || {
                black_box(ig_st.par_batch_gains(&chol_cands, t))
            });
        }
        let dpp = DppLogDet::new(&pk, 1.0, 0.5);
        let mut dpp_st = dpp.state();
        for i in 0..24 {
            dpp_st.push((i * 17 + 64) % pk_n);
        }
        for &t in &[1usize, 4, 8] {
            b.bench(&format!("dpp: 64 gains, engine ({t}t)"), || {
                black_box(dpp_st.par_batch_gains(&chol_cands, t))
            });
        }
    }

    // Sections 2+ run on the fast-mode-sized dataset.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), 1));
    let fac = FacilityLocation::from_dataset(&ds);
    if let Ok(engine) = greedi::runtime::Engine::load_default() {
        let engine = Arc::new(engine);
        let backend =
            greedi::runtime::XlaFacilityBackend::new(&engine, &ds_gain, &ds_gain.ids()).unwrap();
        let fac_xla =
            FacilityLocation::from_dataset(&ds_gain).with_backend(Arc::new(backend));
        let mut st = fac_xla.state();
        st.push(100);
        b.bench("facility: 64 gains, XLA artifact backend", || {
            black_box(st.batch_gains(&cands))
        });
    } else {
        println!("(XLA backend bench skipped — run `make artifacts`)");
    }

    // ---- 2. greedy economics ----------------------------------------------
    let ground = ds.ids();
    let con = Cardinality::new(k);
    let mut rng = Rng::new(2);
    let plain = b.bench("greedy: plain", || {
        black_box(Greedy.maximize(&fac, &ground, &con, &mut rng).oracle_calls)
    });
    let _ = plain;
    b.bench("greedy: lazy (Minoux)", || {
        black_box(LazyGreedy.maximize(&fac, &ground, &con, &mut rng).oracle_calls)
    });
    b.bench("greedy: lazy, 8 oracle threads", || {
        black_box(
            LazyGreedy
                .maximize_threaded(&fac, &ground, &con, &mut rng, 8)
                .oracle_calls,
        )
    });
    b.bench("greedy: stochastic (ε=0.1)", || {
        black_box(
            StochasticGreedy::default()
                .maximize(&fac, &ground, &con, &mut rng)
                .oracle_calls,
        )
    });
    {
        let mut r = Rng::new(3);
        let pc = Greedy.maximize(&fac, &ground, &con, &mut r).oracle_calls;
        let lc = LazyGreedy.maximize(&fac, &ground, &con, &mut r).oracle_calls;
        let sc = StochasticGreedy::default()
            .maximize(&fac, &ground, &con, &mut r)
            .oracle_calls;
        println!("  oracle calls: plain={pc} lazy={lc} stochastic={sc}");
    }

    // ---- 3. info-gain: incremental Cholesky vs dense logdet ----------------
    let pk = Arc::new(parkinsons_like(if fast { 400 } else { 1_500 }, 22, 4));
    let ig = InfoGain::paper_params(&pk);
    let sel: Vec<usize> = (0..k).collect();
    b.bench("infogain: incremental Cholesky eval", || {
        black_box(ig.eval(&sel))
    });
    b.bench("infogain: dense logdet eval", || {
        let kk = sel.len();
        let mut m = Matrix::identity(kk);
        for i in 0..kk {
            for j in 0..kk {
                m[(i, j)] += ig.scaled_kernel(sel[i], sel[j]);
            }
        }
        black_box(m.logdet().unwrap())
    });
    b.bench("cholesky: 64 incremental pushes", || {
        let mut inc = IncrementalCholesky::new();
        for i in 0..64usize {
            let a_se: Vec<f64> = (0..i).map(|j| 0.1 / (1.0 + (i + j) as f64)).collect();
            inc.push(2.0, &a_se);
        }
        black_box(inc.logdet())
    });

    let problem = FacilityProblem::new(&ds);

    // ---- 3b. trace overhead -------------------------------------------------
    // Pins the observability contract: the disabled path is one relaxed
    // load + branch (no allocation — "span disabled" must sit within noise
    // of the empty loop), and a fully traced protocol run stays close to
    // its untraced twin. The trace file goes to a temp path we remove.
    {
        use greedi::util::trace;
        trace::disable();
        b.bench("trace: span disabled, x10k", || {
            for i in 0..10_000u64 {
                let _sp = trace::span_with("bench.noop", || vec![("i", i.into())]);
                black_box(i);
            }
        });
        b.bench("trace: empty loop, x10k", || {
            for i in 0..10_000u64 {
                black_box(i);
            }
        });
        b.bench("protocol: greedi 2-round untraced (m=8)", || {
            black_box(Greedi.run(&problem, &RunSpec::new(8, k).seed(1)).value)
        });
        let tpath = std::env::temp_dir().join(format!("greedi_bench_trace_{}", std::process::id()));
        trace::enable(&tpath);
        b.bench("trace: span enabled, x10k", || {
            for i in 0..10_000u64 {
                let _sp = trace::span_with("bench.noop", || vec![("i", i.into())]);
                black_box(i);
            }
            trace::clear_events();
        });
        b.bench("protocol: greedi 2-round traced (m=8)", || {
            let v = Greedi.run(&problem, &RunSpec::new(8, k).seed(1)).value;
            trace::clear_events();
            black_box(v)
        });
        trace::disable();
        trace::clear_events();
        let _ = std::fs::remove_file(&tpath);
    }

    // ---- 4. protocol end-to-end --------------------------------------------
    b.bench("protocol: centralized lazy greedy", || {
        black_box(centralized(&problem, k, "lazy", 1).value)
    });
    b.bench("protocol: greedi 2-round (m=8)", || {
        black_box(Greedi.run(&problem, &RunSpec::new(8, k).seed(1)).value)
    });
    b.bench("protocol: greedi local mode (m=8)", || {
        black_box(Greedi.run(&problem, &RunSpec::new(8, k).local().seed(1)).value)
    });
    b.bench("protocol: greedi 2-round (m=8, 4 threads)", || {
        black_box(
            Greedi
                .run(&problem, &RunSpec::new(8, k).threads(4).seed(1))
                .value,
        )
    });

    println!("\n== summary ==");
    if let Some(s) = b.speedup(
        "facility: 64 gains, naive eval() diffs",
        "facility: 64 gains, cached-curmin state",
    ) {
        println!("cached-curmin speedup over naive eval: {s:.1}x");
    }
    for threads in [1usize, 2, 4, 8] {
        if let Some(s) = b.speedup(
            "facility: 64 gains, serial scalar (pre-PR)",
            &format!("facility: 64 gains, sharded engine ({threads}t)"),
        ) {
            println!("sharded gain engine ({threads}t) speedup over pre-PR serial scalar: {s:.1}x");
        }
    }
    for &w in &[1_000usize, 10_000, 100_000] {
        for &t in &[1usize, 2, 4, 8] {
            if let Some(s) = b.speedup(
                &format!("smallwin |W|={w}: 16 gains, scoped-spawn ({t}t)"),
                &format!("smallwin |W|={w}: 16 gains, executor ({t}t)"),
            ) {
                println!("executor vs scoped-spawn (|W|={w}, {t}t): {s:.2}x");
            }
        }
    }
    if let Some(s) = b.speedup(
        "kernel: sq_dist scalar, d=64 x 10k",
        "kernel: sq_dist dispatched, d=64 x 10k",
    ) {
        println!("dispatched distance kernel ({}) speedup over scalar: {s:.2}x", kernel_name());
    }
    for op in ["infogain", "dpp"] {
        for &t in &[4usize, 8] {
            if let Some(s) = b.speedup(
                &format!("{op}: 64 gains, engine (1t)"),
                &format!("{op}: 64 gains, engine ({t}t)"),
            ) {
                println!("{op} engine thread scaling ({t}t vs 1t): {s:.2}x");
            }
        }
    }
    if let Some(s) = b.speedup(
        "infogain: dense logdet eval",
        "infogain: incremental Cholesky eval",
    ) {
        println!("incremental Cholesky speedup over dense: {s:.1}x");
    }
    if let Some(s) = b.speedup(
        "protocol: centralized lazy greedy",
        "protocol: greedi 2-round (m=8)",
    ) {
        println!("greedi wallclock speedup vs centralized (1 core, real time): {s:.2}x");
    }
    if let Some(s) = b.speedup("trace: span disabled, x10k", "trace: empty loop, x10k") {
        println!("disabled trace span overhead vs empty loop: {s:.2}x (≈1.0 = branch-only)");
    }
    if let Some(s) = b.speedup(
        "protocol: greedi 2-round traced (m=8)",
        "protocol: greedi 2-round untraced (m=8)",
    ) {
        println!("traced greedi run vs untraced: {s:.2}x");
    }

    // GREEDI_BENCH_JSON=path dumps `op -> ns/iter` for the CI perf trail.
    b.maybe_write_json_env();
}
