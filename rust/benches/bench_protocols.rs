//! `cargo bench` target: the protocol-registry sweep. One workload, one
//! shared `RunSpec`, every protocol in `protocol::by_name` — so any protocol
//! added to the registry is benchmarked for free, in both sequential and
//! threaded map-stage configurations.
//!
//! Set `GREEDI_BENCH_FAST=1` for a CI-speed pass;
//! `GREEDI_BENCH_JSON=BENCH_protocols.json` dumps `op -> ns/iter` for the
//! CI perf trail (same shape as `BENCH_hotpath.json`).

use std::sync::Arc;

use greedi::coordinator::protocol::{self, FaultPlan, Protocol, RecoveryPolicy, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::util::bench::{black_box, Bencher};

fn main() {
    let fast = std::env::var("GREEDI_BENCH_FAST").ok().as_deref() == Some("1");
    let (n, m, k) = if fast { (600, 4, 8) } else { (4_000, 8, 24) };
    let mut b = Bencher::new(1, if fast { 2 } else { 5 });

    println!("== protocol registry benchmarks (n={n}, m={m}, k={k}) ==\n");

    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), 1));
    let problem = FacilityProblem::new(&ds);
    let spec = RunSpec::new(m, k).seed(1);

    // ---- every registered protocol under the one shared spec --------------
    let mut values = Vec::new();
    for name in protocol::NAMES {
        let proto = protocol::by_name(name).expect("registry");
        let mut last = 0.0;
        b.bench(&format!("protocol: {name}"), || {
            last = proto.run(&problem, &spec).value;
            black_box(last)
        });
        values.push((name, last));
    }

    // ---- threaded map stage: the uniform `threads` knob --------------------
    for threads in [2, 4] {
        let spec_t = spec.clone().threads(threads);
        b.bench(&format!("protocol: greedi ({threads} threads)"), || {
            black_box(
                protocol::by_name("greedi")
                    .expect("registry")
                    .run(&problem, &spec_t)
                    .value,
            )
        });
    }

    // ---- accumulation-tree merge: fan-in r at small and wide clusters ------
    // flat is the classic single-root merge (fanout >= m); the r = 2 / 4
    // rows pay extra levels to cap the root's candidate pool at r·κ
    for tree_m in [10usize, 100] {
        for fanout in [2usize, 4, 0] {
            let label = if fanout == 0 {
                format!("protocol: greedi (m={tree_m}, flat merge)")
            } else {
                format!("protocol: greedi (m={tree_m}, tree r={fanout})")
            };
            let spec_tree = if fanout == 0 {
                RunSpec::new(tree_m, k).seed(1)
            } else {
                RunSpec::new(tree_m, k).seed(1).fanout(fanout)
            };
            b.bench(&label, || {
                black_box(
                    protocol::by_name("greedi")
                        .expect("registry")
                        .run(&problem, &spec_tree)
                        .value,
                )
            });
        }
    }

    // ---- fault-tolerance overhead: retries, replication, crash recovery ----
    let spec_retry = spec.clone().faults(FaultPlan::new(0.2, 8, 1));
    b.bench("protocol: greedi (retry, fail_p=0.2)", || {
        black_box(
            protocol::by_name("greedi")
                .expect("registry")
                .run(&problem, &spec_retry)
                .value,
        )
    });
    let spec_c2 = spec.clone().multiplicity(2);
    b.bench("protocol: greedi (c=2 replication)", || {
        black_box(
            protocol::by_name("greedi")
                .expect("registry")
                .run(&problem, &spec_c2)
                .value,
        )
    });
    let spec_recover = spec
        .clone()
        .multiplicity(2)
        .recovery(RecoveryPolicy::SurvivorMerge)
        .faults(FaultPlan::none().crash_tasks(vec![0]));
    b.bench("protocol: greedi (c=2, crash + survivor-merge)", || {
        black_box(
            protocol::by_name("greedi")
                .expect("registry")
                .run(&problem, &spec_recover)
                .value,
        )
    });

    // ---- checkpoint overhead: resume recovery at B ∈ {off, 8, 64} ----------
    // The crash + salvage path is where checkpoints pay; the no-crash row at
    // B=0 is the PR 7 baseline the others are measured against.
    for checkpoint_every in [0usize, 8, 64] {
        let spec_ckpt = spec
            .clone()
            .multiplicity(2)
            .recovery(RecoveryPolicy::Resume)
            .checkpoint_every(checkpoint_every)
            .faults(FaultPlan::none().crash_tasks(vec![0]).crash_progress(0.75));
        let label = if checkpoint_every == 0 {
            "protocol: greedi (c=2, crash + resume, ckpt=off)".to_string()
        } else {
            format!("protocol: greedi (c=2, crash + resume, ckpt={checkpoint_every})")
        };
        b.bench(&label, || {
            black_box(
                protocol::by_name("greedi")
                    .expect("registry")
                    .run(&problem, &spec_ckpt)
                    .value,
            )
        });
    }

    println!("\n== values under the shared spec ==");
    let central = values
        .iter()
        .find(|(n, _)| *n == "centralized")
        .map(|(_, v)| *v)
        .unwrap_or(1.0);
    for (name, v) in &values {
        println!("  {name:<16} f(S)={v:<12.5} ratio={:.4}", v / central);
    }

    if let Some(s) = b.speedup("protocol: greedi", "protocol: greedi (4 threads)") {
        println!("\ngreedi map-stage speedup with 4 threads: {s:.2}x");
    }

    // GREEDI_BENCH_JSON=path dumps `op -> ns/iter` for the CI perf trail.
    if let Some(path) = b.maybe_write_json_env() {
        println!("wrote {path}");
    }
}
