//! `cargo bench` target: regenerates every paper table/figure at bench
//! scale and times the end-to-end protocol runs (criterion is not in the
//! offline dependency closure — `util::bench` provides the harness).
//!
//! Set `GREEDI_BENCH_FAST=1` for a CI-speed pass, `GREEDI_BENCH_FULL=1` to
//! lift sizes toward paper scale.

use greedi::experiments::{self, ExpOpts};
use greedi::util::bench::Bencher;

fn main() {
    let full = std::env::var("GREEDI_BENCH_FULL").ok().as_deref() == Some("1");
    let fast = std::env::var("GREEDI_BENCH_FAST").ok().as_deref() == Some("1");
    let opts = ExpOpts {
        n: if fast { Some(300) } else { None },
        trials: if fast { 1 } else { 2 },
        full,
        ..Default::default()
    };
    let mut b = Bencher::new(0, 1); // figure harnesses are end-to-end: 1 iter

    println!("== figure regeneration benchmarks (n overrides: fast={fast}, full={full}) ==\n");

    let mut reports = Vec::new();
    macro_rules! fig {
        ($name:literal, $module:ident) => {
            let mut out = None;
            b.bench($name, || {
                out = Some(experiments::$module::run(&opts));
            });
            reports.push(out.unwrap());
        };
    }
    fig!("fig4: exemplar clustering sweeps", fig4);
    fig!("fig5: large-scale local clustering", fig5);
    fig!("fig6: GP active set (parkinsons)", fig6);
    fig!("fig7: GP active set (yahoo)", fig7);
    fig!("fig8: speedup vs m", fig8);
    fig!("fig9: max-cut (non-monotone)", fig9);
    fig!("fig10: coverage vs GreedyScaling", fig10);
    fig!("theory: Thm 3/4 + Table 1 checks", theory);

    println!("\n== figure outputs ==\n");
    for r in &reports {
        r.print();
    }
}
