//! `cargo bench` target: streaming-subsystem throughput — elements/sec of
//! the one-pass batched sieve as the batch size sweeps (the batched ladder
//! pricing amortizing over wider `par_batch_gains` calls), thread scaling
//! at a fixed batch, and the `stream_greedi` protocol end-to-end against
//! two-round GreeDi.
//!
//! `GREEDI_BENCH_FAST=1` shrinks sizes for CI;
//! `GREEDI_BENCH_JSON=BENCH_stream.json` dumps `op -> ns/iter` for the
//! machine-readable perf trail (uploaded as a CI artifact alongside
//! `BENCH_hotpath.json`).

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::objective::facility::FacilityLocation;
use greedi::stream::{sieve_stream, VecSource};
use greedi::util::bench::{black_box, Bencher};

fn main() {
    let fast = std::env::var("GREEDI_BENCH_FAST").ok().as_deref() == Some("1");
    let (n, k) = if fast { (1_200, 12) } else { (8_000, 24) };
    let d = 16;
    let epsilon = 0.2;
    let mut b = Bencher::new(1, if fast { 2 } else { 5 });

    println!("== streaming benchmarks (n={n}, d={d}, k={k}, ε={epsilon}) ==\n");

    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, d), 1));
    let fac = FacilityLocation::from_dataset(&ds);
    let order = ds.ids();

    // ---- 1. elements/sec vs batch size (the headline curve) --------------
    for batch in [1usize, 16, 256, 4_096] {
        let mean_s = b
            .bench(&format!("stream: sieve one pass (batch={batch})"), || {
                let mut src = VecSource::new(order.clone());
                black_box(sieve_stream(&fac, &mut src, k, epsilon, batch, 1).value)
            })
            .mean_s;
        if mean_s > 0.0 {
            println!("  -> {:.0} elements/sec", n as f64 / mean_s);
        }
    }

    // ---- 2. thread scaling at a fixed batch -------------------------------
    for threads in [1usize, 2, 4, 8] {
        b.bench(&format!("stream: sieve one pass (batch=256, {threads}t)"), || {
            let mut src = VecSource::new(order.clone());
            black_box(sieve_stream(&fac, &mut src, k, epsilon, 256, threads).value)
        });
    }

    // ---- 3. protocol end-to-end: one-pass sieve→merge vs two-round --------
    let problem = FacilityProblem::new(&ds);
    let spec = RunSpec::new(8, k).epsilon(epsilon).batch(256).seed(1);
    let mut peak = 0usize;
    let mut bound = 0usize;
    b.bench("protocol: stream_greedi (m=8)", || {
        let r = protocol::by_name("stream_greedi")
            .expect("registry")
            .run(&problem, &spec);
        if let Some(s) = &r.stream {
            peak = s.peak_live();
            bound = s.live_bound;
        }
        black_box(r.value)
    });
    println!("  -> peak live candidates per machine: {peak} (bound {bound})");
    b.bench("protocol: stream_greedi (m=8, 4 threads)", || {
        black_box(
            protocol::by_name("stream_greedi")
                .expect("registry")
                .run(&problem, &spec.clone().threads(4))
                .value,
        )
    });
    b.bench("protocol: greedi 2-round (m=8)", || {
        black_box(
            protocol::by_name("greedi")
                .expect("registry")
                .run(&problem, &spec)
                .value,
        )
    });

    println!("\n== summary ==");
    if let Some(s) = b.speedup(
        "stream: sieve one pass (batch=1)",
        "stream: sieve one pass (batch=256)",
    ) {
        println!("batched ladder pricing speedup (batch 256 vs 1): {s:.1}x");
    }
    if let Some(s) = b.speedup(
        "stream: sieve one pass (batch=256, 1t)",
        "stream: sieve one pass (batch=256, 8t)",
    ) {
        println!("sieve thread scaling (8t vs 1t, batch 256): {s:.1}x");
    }

    // GREEDI_BENCH_JSON=path dumps `op -> ns/iter` for the CI perf trail.
    b.maybe_write_json_env();
}
