#!/usr/bin/env python3
"""Validate a greedi Chrome trace written by ``util::trace``.

Usage: trace_check.py TRACE.json [--require NAME ...] [--min-spans N]

Checks (exit 1 on any failure — this one IS a gate, unlike bench_compare):

1. the file is valid JSON with a ``traceEvents`` array and a ``metrics``
   object (the document Perfetto / chrome://tracing loads);
2. every event carries the Chrome ``trace_event`` essentials: ``name``,
   ``ph`` ("X" complete span or "i" instant), ``tid``, ``ts``, and a
   non-negative ``dur`` on spans;
3. at least ``--min-spans`` spans total (default 1);
4. every ``--require``'d span name appears at least once with nonzero
   count — CI passes the MapReduce stage names so a silently
   un-instrumented stage fails the smoke test;
5. the NDJSON sidecar (``TRACE.json.ndjson``), when present, is one
   parseable object per line.

Prints a per-name span count table so the CI log doubles as a quick
coverage report.
"""

import json
import os
import sys
from collections import Counter


def fail(msg):
    print(f"trace_check: FAIL: {msg}")
    sys.exit(1)


def main(argv):
    path = None
    required = []
    min_spans = 1
    i = 1
    while i < len(argv):
        a = argv[i]
        if a == "--require":
            i += 1
            required.append(argv[i])
        elif a.startswith("--require="):
            required.append(a.split("=", 1)[1])
        elif a == "--min-spans":
            i += 1
            min_spans = int(argv[i])
        elif a.startswith("--min-spans="):
            min_spans = int(a.split("=", 1)[1])
        elif path is None:
            path = a
        else:
            print(__doc__)
            return 2
        i += 1
    if path is None:
        print(__doc__)
        return 2

    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"cannot parse {path}: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("no traceEvents array")
    if not isinstance(doc.get("metrics"), dict):
        fail("no metrics object (counters/gauges/histograms snapshot)")

    spans = Counter()
    instants = Counter()
    for idx, e in enumerate(events):
        for key in ("name", "ph", "tid", "ts"):
            if key not in e:
                fail(f"event {idx} missing {key!r}: {e}")
        ph = e["ph"]
        if ph == "X":
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                fail(f"span {e['name']!r} has bad dur {e.get('dur')!r}")
            spans[e["name"]] += 1
        elif ph == "i":
            instants[e["name"]] += 1
        else:
            fail(f"event {idx} has unexpected ph {ph!r}")

    total = sum(spans.values())
    if total < min_spans:
        fail(f"only {total} spans, expected >= {min_spans}")
    missing = [name for name in required if spans.get(name, 0) == 0]
    if missing:
        fail(f"required span(s) absent: {', '.join(missing)}")

    sidecar = path + ".ndjson"
    nd_lines = 0
    if os.path.exists(sidecar):
        with open(sidecar) as f:
            for ln, line in enumerate(f, 1):
                if not line.strip():
                    continue
                try:
                    json.loads(line)
                except ValueError as e:
                    fail(f"{sidecar}:{ln}: unparseable NDJSON line: {e}")
                nd_lines += 1

    print(f"trace_check: OK: {total} spans / {sum(instants.values())} instants "
          f"across {len(spans)} span names; {nd_lines} NDJSON rows")
    width = max((len(n) for n in spans), default=4)
    for name, count in sorted(spans.items()):
        req = "  (required)" if name in required else ""
        print(f"  {name:<{width}}  {count:>7}{req}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
