#!/usr/bin/env python3
"""Warn-only before/after comparison of util::bench JSON files.

Usage: bench_compare.py BASELINE.json CURRENT.json [--threshold 1.5]

Both files are the flat ``{"op name": ns_per_iter, ...}`` objects written by
``GREEDI_BENCH_JSON=path cargo bench``. The baseline is the committed copy
(or a CI artifact from the base branch); the current file is the run that
just finished. Prints a per-op ratio table and a WARN line for every op
slower than ``threshold`` x baseline.

ALWAYS exits 0: CI bench runners are noisy shared machines, and the
committed baselines started life as stubs (the PR-2..4 authoring containers
had no Rust toolchain), so this step is a perf *trail*, not a gate. Ops
missing on either side are reported and skipped; a stub / empty baseline
(no numeric ops, e.g. only a ``_meta`` note) short-circuits with a notice —
regenerate the committed baseline from the CI artifact to arm the
comparison.
"""

import json
import sys


def load_ops(path):
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_compare: cannot read {path}: {e} — skipping comparison")
        return None
    return {
        k: float(v)
        for k, v in raw.items()
        if isinstance(v, (int, float)) and not k.startswith("_")
    }


def main(argv):
    threshold = 1.5
    args = []
    i = 1
    while i < len(argv):
        a = argv[i]
        if a.startswith("--threshold"):
            if "=" in a:
                threshold = float(a.split("=", 1)[1])
            elif i + 1 < len(argv):
                i += 1
                threshold = float(argv[i])
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 0
    base, cur = load_ops(args[0]), load_ops(args[1])
    if base is None or cur is None:
        return 0
    if not base:
        print(f"bench_compare: baseline {args[0]} has no numeric ops (stub?) — "
              "nothing to compare; commit a CI-generated baseline to arm this step")
        return 0
    if not cur:
        print(f"bench_compare: current {args[1]} has no numeric ops — skipping")
        return 0

    shared = [op for op in cur if op in base]
    gone = sorted(op for op in base if op not in cur)
    new = sorted(op for op in cur if op not in base)
    warns = 0
    width = max((len(op) for op in shared), default=8)
    print(f"{'op':<{width}}  {'base ns':>12}  {'cur ns':>12}  ratio")
    for op in shared:
        b, c = base[op], cur[op]
        ratio = c / b if b > 0 else float("inf")
        flag = ""
        if ratio > threshold:
            flag = f"  WARN >{threshold}x"
            warns += 1
        print(f"{op:<{width}}  {b:>12.1f}  {c:>12.1f}  {ratio:>5.2f}{flag}")
    for op in new:
        print(f"(new op, no baseline: {op})")
    for op in gone:
        print(f"(op dropped since baseline: {op})")
    if warns:
        print(f"bench_compare: {warns} op(s) slower than {threshold}x baseline "
              "(warn-only; CI runners are noisy — investigate if it persists)")
    else:
        print("bench_compare: no regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
