#!/usr/bin/env python3
"""Warn-only before/after comparison of util::bench JSON files.

Usage: bench_compare.py BASELINE.json CURRENT.json [--threshold 1.5]

Both files are the flat ``{"op name": ns_per_iter, ...}`` objects written by
``GREEDI_BENCH_JSON=path cargo bench``. The baseline is the committed copy
(or a CI artifact from the base branch); the current file is the run that
just finished.

Output: when ``GITHUB_STEP_SUMMARY`` is set (GitHub Actions), a per-op
delta table is appended to the job summary as GitHub-flavored markdown
(ratio column, WARN flags, new/dropped ops) so regressions are readable
from the run page without digging through logs; otherwise the same table
prints to stdout in plain text.

ALWAYS exits 0: CI bench runners are noisy shared machines, and the
committed baselines started life as stubs (the PR-2..4 authoring containers
had no Rust toolchain), so this step is a perf *trail*, not a gate. Ops
missing on either side are reported and skipped; a stub / empty baseline
(no numeric ops, e.g. only a ``_meta`` note) short-circuits with a notice —
regenerate the committed baseline from the CI artifact to arm the
comparison.
"""

import json
import os
import sys


def load_ops(path, notes):
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError) as e:
        notes.append(f"bench_compare: cannot read {path}: {e} — skipping comparison")
        return None
    return {
        k: float(v)
        for k, v in raw.items()
        if isinstance(v, (int, float)) and not k.startswith("_")
    }


def emit(lines_markdown, lines_plain):
    """Job summary when running under Actions, stdout otherwise."""
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("\n".join(lines_markdown) + "\n")
        # leave a breadcrumb in the log so the step isn't silent
        print("bench_compare: delta table written to the job summary")
    else:
        print("\n".join(lines_plain))


def main(argv):
    threshold = 1.5
    args = []
    i = 1
    while i < len(argv):
        a = argv[i]
        if a.startswith("--threshold"):
            if "=" in a:
                threshold = float(a.split("=", 1)[1])
            elif i + 1 < len(argv):
                i += 1
                threshold = float(argv[i])
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 0
    notes = []
    base, cur = load_ops(args[0], notes), load_ops(args[1], notes)
    if base is None or cur is None:
        emit([f"> {n}" for n in notes], notes)
        return 0
    if not base:
        msg = (f"bench_compare: baseline {args[0]} has no numeric ops (stub?) — "
               "nothing to compare; commit a CI-generated baseline to arm this step")
        emit([f"> {msg}"], [msg])
        return 0
    if not cur:
        msg = f"bench_compare: current {args[1]} has no numeric ops — skipping"
        emit([f"> {msg}"], [msg])
        return 0

    shared = [op for op in cur if op in base]
    gone = sorted(op for op in base if op not in cur)
    new = sorted(op for op in cur if op not in base)
    warns = 0

    def md_op(op):
        # op names contain literal pipes (e.g. "smallwin |W|=1000: ...") —
        # escape them or they split the markdown table's cells.
        return "`" + op.replace("|", "\\|") + "`"

    name = os.path.basename(args[1])
    md = [f"### bench_compare: `{name}` vs committed baseline", "",
          "| op | base ns | cur ns | ratio | |",
          "|---|---:|---:|---:|---|"]
    width = max((len(op) for op in shared), default=8)
    plain = [f"{'op':<{width}}  {'base ns':>12}  {'cur ns':>12}  ratio"]
    for op in shared:
        b, c = base[op], cur[op]
        ratio = c / b if b > 0 else float("inf")
        warn = ratio > threshold
        if warn:
            warns += 1
        flag_md = f"⚠️ WARN >{threshold}x" if warn else ""
        flag_plain = f"  WARN >{threshold}x" if warn else ""
        md.append(f"| {md_op(op)} | {b:.1f} | {c:.1f} | {ratio:.2f} | {flag_md} |")
        plain.append(f"{op:<{width}}  {b:>12.1f}  {c:>12.1f}  {ratio:>5.2f}{flag_plain}")
    for op in new:
        md.append(f"| {md_op(op)} | — | {cur[op]:.1f} | new | |")
        plain.append(f"(new op, no baseline: {op})")
    for op in gone:
        md.append(f"| {md_op(op)} | {base[op]:.1f} | — | dropped | |")
        plain.append(f"(op dropped since baseline: {op})")
    if warns:
        verdict = (f"bench_compare: {warns} op(s) slower than {threshold}x baseline "
                   "(warn-only; CI runners are noisy — investigate if it persists)")
    else:
        verdict = "bench_compare: no regressions beyond threshold"
    md += ["", f"> {verdict}", ""]
    plain.append(verdict)
    emit(md, plain)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
