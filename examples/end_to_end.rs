//! END-TO-END DRIVER — proves all three layers compose on a real workload.
//!
//! Pipeline-paper validation per the project brief: run the full system on
//! a real small workload and report the paper's headline metric.
//!
//! * **Layer 1/2**: the Pallas facility-gain kernel inside the JAX graph,
//!   AOT-compiled by `make artifacts` into `artifacts/*.hlo.txt`;
//! * **Runtime**: the rust PJRT engine loads and executes those artifacts
//!   (no python anywhere in this process; requires `--features xla`);
//! * **Layer 3**: every distributed protocol drives the simulated MapReduce
//!   cluster through the unified `protocol::by_name` + `RunSpec` API, with
//!   the XLA gain oracle on the hot path when available.
//!
//! Headline metric (paper §6.1): distributed/centralized utility ratio —
//! expected ≈0.98 for GreeDi, clearly lower for the naive protocols.
//!
//! ```sh
//! # vendor the `xla` crate first (see rust/Cargo.toml [features]), then:
//! make artifacts && cargo run --release --features xla --example end_to_end
//! # without the vendored crate/artifacts it falls back to the scalar oracle
//! ```

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::runtime::{Engine, XlaBackendFactory};
use greedi::util::args::Args;
use greedi::util::table::Table;
use greedi::util::timer::Timer;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 10_000);
    let d = args.get_usize("d", 32);
    let k = args.get_usize("k", 64);
    let m = args.get_usize("m", 10);
    let threads = args.get_usize("threads", 1);
    let seed = args.get_u64("seed", 42);
    let scalar_only = args.has_flag("scalar"); // debug escape hatch

    println!("==== GreeDi end-to-end driver ====");
    println!("workload: tiny-image surrogate, n={n}, d={d}, k={k}, m={m}\n");

    // ---- data ------------------------------------------------------------
    let t = Timer::start();
    let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, d), seed));
    println!("[1/4] dataset generated ({:.2}s)", t.elapsed_secs());

    // ---- AOT artifacts through PJRT ---------------------------------------
    let mut problem = FacilityProblem::new(&data);
    let mut engine_execs: Option<Arc<Engine>> = None;
    if scalar_only {
        println!("[2/4] scalar gain oracle (--scalar)");
    } else {
        let t = Timer::start();
        match Engine::load_default() {
            Ok(engine) => {
                let engine = Arc::new(engine);
                problem = problem.with_backend_factory(Arc::new(XlaBackendFactory {
                    engine: Arc::clone(&engine),
                }));
                println!(
                    "[2/4] PJRT engine up: {} artifacts compiled ({:.2}s) — python is NOT running",
                    engine.manifest.entries.len(),
                    t.elapsed_secs()
                );
                engine_execs = Some(engine);
            }
            Err(e) => {
                println!("[2/4] scalar gain oracle (PJRT unavailable: {e})");
            }
        }
    }

    // ---- centralized reference -------------------------------------------
    let spec = RunSpec::new(m, k).threads(threads).seed(seed);
    let t = Timer::start();
    let central = protocol::by_name("centralized").expect("registry").run(&problem, &spec);
    println!(
        "[3/4] centralized lazy greedy: f={:.5}, {} oracle calls ({:.2}s)\n",
        central.value,
        central.oracle_calls,
        t.elapsed_secs()
    );

    // ---- distributed protocols over the simulated cluster ------------------
    println!("[4/4] distributed protocols (m={m} machines, unified RunSpec):\n");
    let mut table = Table::new(
        "END-TO-END RESULTS (headline: distributed/centralized ratio)",
        &["protocol", "f(S)", "ratio", "oracle calls", "sim-parallel time", "comm (ids)"],
    );
    let mut add = |name: &str, r: &greedi::coordinator::metrics::RunMetrics| {
        table.row(&[
            name.into(),
            format!("{:.5}", r.value),
            format!("{:.4}", r.ratio_vs(central.value)),
            r.oracle_calls.to_string(),
            format!("{:.3}s", r.sim_time()),
            r.job.shuffled_elements.to_string(),
        ]);
    };

    let greedi = protocol::by_name("greedi").expect("registry");
    let grd_global = greedi.run(&problem, &spec);
    add("greedi (global)", &grd_global);
    let grd_local = greedi.run(&problem, &spec.clone().local());
    add("greedi (local §4.5)", &grd_local);
    let grd_over = greedi.run(&problem, &spec.clone().alpha(2.0));
    add("greedi (α=2)", &grd_over);
    for name in protocol::BASELINE_NAMES {
        let r = protocol::by_name(name).expect("registry").run(&problem, &spec);
        add(&r.name.clone(), &r);
    }
    table.print();

    if let Some(engine) = engine_execs {
        println!(
            "PJRT executions on the hot path: {}",
            engine.exec_count.load(std::sync::atomic::Ordering::Relaxed)
        );
    }

    let ratio = grd_global.ratio_vs(central.value);
    println!("\nheadline: GreeDi/centralized = {ratio:.4} (paper: ≈0.98)");
    assert!(ratio > 0.9, "end-to-end regression: ratio {ratio}");
    println!("end_to_end OK");
}
