//! Active-set selection for sparse GP inference (paper §3.4.1 / §6.2):
//! maximize the information gain f(S) = ½ log det(I + σ⁻²K_SS) over
//! Parkinsons-Telemonitoring-like voice features with the paper's kernel
//! (squared exponential, h = 0.75, σ = 1).
//!
//! ```sh
//! cargo run --release --example active_set_gp -- --n 5875 --k 50 --m 10
//! ```

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::InfoGainProblem;
use greedi::coordinator::Problem;
use greedi::data::synth::parkinsons_like;
use greedi::util::args::Args;
use greedi::util::table::Table;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 5_875); // the paper's exact corpus size
    let k = args.get_usize("k", 50);
    let m = args.get_usize("m", 10);
    let threads = args.get_usize("threads", 1);
    let seed = args.get_u64("seed", 11);

    println!("== GP active-set selection: n={n}, d=22, k={k}, m={m}, h=0.75, σ=1 ==\n");
    let data = Arc::new(parkinsons_like(n, 22, seed));
    let problem = InfoGainProblem::paper_params(&data);

    let spec = RunSpec::new(m, k).threads(threads).seed(seed);
    let central = protocol::by_name("centralized").expect("registry").run(&problem, &spec);
    let grd = protocol::by_name("greedi").expect("registry").run(&problem, &spec);

    let mut t = Table::new("information gain", &["protocol", "f(S)", "ratio"]);
    t.row(&["centralized".into(), format!("{:.4}", central.value), "1.000".into()]);
    t.row(&[
        "greedi".into(),
        format!("{:.4}", grd.value),
        format!("{:.3}", grd.ratio_vs(central.value)),
    ]);
    for name in protocol::BASELINE_NAMES {
        let r = protocol::by_name(name).expect("registry").run(&problem, &spec);
        t.row(&[
            r.name.clone(),
            format!("{:.4}", r.value),
            format!("{:.3}", r.ratio_vs(central.value)),
        ]);
    }
    t.print();

    // Marginal-information curve of the GreeDi active set: how much each
    // successive exemplar adds (diminishing returns made visible).
    let obj = problem.global();
    let mut st = obj.state();
    println!("\nper-element information increments (GreeDi order):");
    let mut line = String::new();
    for (i, &e) in grd.solution.iter().enumerate() {
        let inc = st.push(e);
        line.push_str(&format!("{inc:.3} "));
        if (i + 1) % 10 == 0 {
            println!("  {line}");
            line.clear();
        }
    }
    if !line.is_empty() {
        println!("  {line}");
    }
    println!("\ntotal = {:.4} nats (vs centralized {:.4})", st.value(), central.value);
}
