//! Non-monotone distributed maximization (paper §6.3): maximum directed cut
//! on a Facebook-like message network, solved on each partition with
//! RandomGreedy (Buchbinder et al. 2014) and locally evaluated objectives
//! (cross-partition links disconnected) — exactly the paper's setup.
//!
//! ```sh
//! cargo run --release --example maxcut_social -- --k 20 --m 10
//! ```

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::CutProblem;
use greedi::data::graph::social_network;
use greedi::util::args::Args;
use greedi::util::stats::summarize;
use greedi::util::table::Table;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 1_899); // paper's UCI network size
    let edges = args.get_usize("edges", 20_296);
    let k = args.get_usize("k", 20);
    let m = args.get_usize("m", 10);
    let threads = args.get_usize("threads", 1);
    let trials = args.get_usize("trials", 5);
    let seed = args.get_u64("seed", 3);

    println!("== max-cut: n={n}, directed edges={edges}, k={k}, m={m} (RandomGreedy) ==\n");
    let g = Arc::new(social_network(n, edges, seed));
    let problem = CutProblem::new(&g);

    // One spec per trial; every protocol sees the identical (seeded) spec.
    let spec_at = |t: usize| {
        RunSpec::new(m, k)
            .algorithm("random_greedy")
            .local()
            .threads(threads)
            .seed(seed + t as u64)
    };

    // RandomGreedy is randomized — report mean ± std over trials, as the
    // paper's Fig. 9 error bars do.
    let central_proto = protocol::by_name("centralized").expect("registry");
    let central: Vec<f64> = (0..trials)
        .map(|t| central_proto.run(&problem, &spec_at(t)).value)
        .collect();
    let cstats = summarize(&central);

    let mut t = Table::new("cut value (mean ± std over trials)", &["protocol", "cut", "ratio"]);
    t.row(&[
        "centralized".into(),
        format!("{:.1}±{:.1}", cstats.mean, cstats.std),
        "1.000".into(),
    ]);

    let greedi = protocol::by_name("greedi").expect("registry");
    let grd: Vec<f64> = (0..trials)
        .map(|t| greedi.run(&problem, &spec_at(t)).value)
        .collect();
    let gstats = summarize(&grd);
    t.row(&[
        "greedi".into(),
        format!("{:.1}±{:.1}", gstats.mean, gstats.std),
        format!("{:.3}", gstats.mean / cstats.mean),
    ]);

    for name in protocol::BASELINE_NAMES {
        let proto = protocol::by_name(name).expect("registry");
        let mut label = String::new();
        let vals: Vec<f64> = (0..trials)
            .map(|t| {
                let r = proto.run(&problem, &spec_at(t));
                label = r.name.clone(); // display label ("random/random", …)
                r.value
            })
            .collect();
        let s = summarize(&vals);
        t.row(&[
            label,
            format!("{:.1}±{:.1}", s.mean, s.std),
            format!("{:.3}", s.mean / cstats.mean),
        ]);
    }
    t.print();
    println!("(paper: GreeDi ≈ 0.90× centralized for max-cut — non-decomposable,\n yet the two-round protocol remains robust)");
}
