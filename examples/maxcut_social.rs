//! Non-monotone distributed maximization (paper §6.3): maximum directed cut
//! on a Facebook-like message network, solved on each partition with
//! RandomGreedy (Buchbinder et al. 2014) and locally evaluated objectives
//! (cross-partition links disconnected) — exactly the paper's setup.
//!
//! ```sh
//! cargo run --release --example maxcut_social -- --k 20 --m 10
//! ```

use std::sync::Arc;

use greedi::coordinator::baselines::Baseline;
use greedi::coordinator::greedi::{centralized, Greedi, GreediConfig};
use greedi::coordinator::CutProblem;
use greedi::data::graph::social_network;
use greedi::util::args::Args;
use greedi::util::stats::summarize;
use greedi::util::table::Table;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 1_899); // paper's UCI network size
    let edges = args.get_usize("edges", 20_296);
    let k = args.get_usize("k", 20);
    let m = args.get_usize("m", 10);
    let trials = args.get_usize("trials", 5);
    let seed = args.get_u64("seed", 3);

    println!("== max-cut: n={n}, directed edges={edges}, k={k}, m={m} (RandomGreedy) ==\n");
    let g = Arc::new(social_network(n, edges, seed));
    let problem = CutProblem::new(&g);

    // RandomGreedy is randomized — report mean ± std over trials, as the
    // paper's Fig. 9 error bars do.
    let central: Vec<f64> = (0..trials)
        .map(|t| centralized(&problem, k, "random_greedy", seed + t as u64).value)
        .collect();
    let cstats = summarize(&central);

    let mut t = Table::new("cut value (mean ± std over trials)", &["protocol", "cut", "ratio"]);
    t.row(&[
        "centralized".into(),
        format!("{:.1}±{:.1}", cstats.mean, cstats.std),
        "1.000".into(),
    ]);

    let grd: Vec<f64> = (0..trials)
        .map(|t| {
            Greedi::new(GreediConfig::new(m, k).algorithm("random_greedy").local())
                .run(&problem, seed + t as u64)
                .value
        })
        .collect();
    let gstats = summarize(&grd);
    t.row(&[
        "greedi".into(),
        format!("{:.1}±{:.1}", gstats.mean, gstats.std),
        format!("{:.3}", gstats.mean / cstats.mean),
    ]);

    for b in Baseline::ALL {
        let vals: Vec<f64> = (0..trials)
            .map(|t| b.run(&problem, m, k, true, "random_greedy", seed + t as u64).value)
            .collect();
        let s = summarize(&vals);
        t.row(&[
            b.label().into(),
            format!("{:.1}±{:.1}", s.mean, s.std),
            format!("{:.3}", s.mean / cstats.mean),
        ]);
    }
    t.print();
    println!("(paper: GreeDi ≈ 0.90× centralized for max-cut — non-decomposable,\n yet the two-round protocol remains robust)");
}
