//! Streaming exemplar clustering — the bounded-memory workload class.
//!
//! A corpus too large to re-scan arrives as a stream: here it is staged to
//! disk as CSV and ingested chunk by chunk (`ChunkedCsvSource`), so only
//! one chunk of rows is ever parsed at a time. A single bounded-memory
//! pass of the batched sieve keeps O(k·log(k)/ε) live candidates — never
//! the corpus — and the distributed `stream_greedi` protocol composes m
//! such passes with one GreeDi-style merge round.
//!
//! Run with: `cargo run --release --example streaming_clustering`

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::loader::save_csv;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::objective::facility::FacilityLocation;
use greedi::stream::{candidate_bound, sieve_stream, ChunkedCsvSource, StreamSource};

fn main() {
    let (n, d, m, k, epsilon, batch) = (3_000usize, 16usize, 5usize, 20usize, 0.1f64, 256usize);
    println!("streaming exemplar clustering: n={n}, d={d}, m={m}, k={k}, ε={epsilon}, batch={batch}\n");

    // Stage the corpus to disk — from here on, ingestion is chunked.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, d), 42));
    let path = std::env::temp_dir().join("greedi_streaming_clustering.csv");
    save_csv(&ds, &path).expect("stage corpus to disk");

    // ---- one machine, one pass, bounded memory ---------------------------
    let f = FacilityLocation::from_dataset(&ds);
    let mut src = ChunkedCsvSource::open(&path).expect("open stream");
    let r = sieve_stream(&f, &mut src, k, epsilon, batch, 1);
    assert!(src.error().is_none(), "stream error: {:?}", src.error());
    println!("single-pass sieve off disk:");
    println!("  rows streamed        : {}", src.rows_read());
    println!("  f(S), |S|            : {:.5}, {}", r.value, r.solution.len());
    println!(
        "  peak live candidates : {} (bound {} = candidate_bound(k, ε); corpus is {}x larger)",
        r.peak_live,
        candidate_bound(k, epsilon),
        n / r.peak_live.max(1)
    );

    // ---- the distributed protocol vs two-round GreeDi --------------------
    let problem = FacilityProblem::new(&ds);
    let spec = RunSpec::new(m, k).epsilon(epsilon).batch(batch).threads(4).seed(7);
    let central = protocol::by_name("centralized").unwrap().run(&problem, &spec);
    println!("\nprotocols under one shared spec:");
    println!("  {}", central.one_line());
    for name in ["greedi", "stream_greedi"] {
        let run = protocol::by_name(name).unwrap().run(&problem, &spec);
        println!("  {}  ratio={:.4}", run.one_line(), run.ratio_vs(central.value));
        if let Some(s) = &run.stream {
            println!(
                "    per-machine peaks {:?} all ≤ bound {} (within: {})",
                s.peak_live_per_machine,
                s.live_bound,
                s.within_bound()
            );
        }
    }

    std::fs::remove_file(&path).ok();
}
