//! The always-on selection service, end to end in one process.
//!
//! Boots a `serve::Server` on an ephemeral port with a drifting streaming
//! dataset, then walks the whole wire surface from a `serve::Client`:
//! ping, warm-up, a query (verified bit-identical to a direct
//! `protocol::by_name` run), concurrent queries through admission control,
//! dataset drift via `advance`, the `stats` latency surface, and a clean
//! shutdown. Against a daemon started separately (`greedi serve`), the
//! client half of this file is all you need.
//!
//! Run with: `cargo run --release --example serve_client`

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::serve::{Client, ServeSpec, Server, WarmState};
use greedi::stream::{DriftSource, StreamOrder, StreamSource};

fn main() {
    let (n, live0) = (2_000usize, 1_200usize);
    let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), 42));

    // ---- boot: warm registry + daemon on an ephemeral port ---------------
    let state = Arc::new(WarmState::new());
    let src = DriftSource::new(&data, data.ids(), StreamOrder::Drift);
    state
        .register_streaming("demo", Arc::clone(&data), Box::new(src), live0)
        .expect("register dataset");
    let mut spec = ServeSpec::default();
    spec.addr = "127.0.0.1:0".into();
    spec.threads = 8;
    spec.max_concurrency = 4;
    let mut server = Server::start(&spec, state).expect("start daemon");
    println!("daemon on {} ({} threads / {} slots)\n", server.addr(), 8, 4);

    let mut client = Client::connect(server.addr()).expect("connect");
    let pong = client.ping().expect("ping");
    println!("ping -> {}", pong.dump());

    // ---- warm the singleton cache, then query ----------------------------
    let w = client.warm(None).expect("warm");
    println!("warm -> {}", w.dump());

    let qspec = RunSpec::new(5, 10).seed(7);
    let reply = client.query("greedi", None, &qspec).expect("query");
    println!(
        "\nquery greedi -> f(S) = {:.5}, |S| = {}, {:.1}us end-to-end ({} threads)",
        reply.value,
        reply.solution.len(),
        reply.latency_us,
        reply.threads_used
    );

    // the served answer is bit-identical to running the protocol directly
    // on the same visible prefix of the drift order
    let mut order_src = DriftSource::new(&data, data.ids(), StreamOrder::Drift);
    let order = order_src.next_batch(n);
    let view = Arc::new(data.subset(&order[..live0]));
    let direct = protocol::by_name("greedi").unwrap().run(&FacilityProblem::new(&view), &qspec);
    assert_eq!(reply.solution, direct.solution);
    assert_eq!(reply.value.to_bits(), direct.value.to_bits());
    println!("  bit-identical to the direct protocol run: yes");

    // ---- concurrent clients through admission control --------------------
    let addr = server.addr();
    let workers: Vec<_> = (0..6)
        .map(|_| {
            let qspec = qspec.clone();
            std::thread::spawn(move || {
                Client::connect(addr).unwrap().query("stream_greedi", None, &qspec).unwrap().value
            })
        })
        .collect();
    let values: Vec<f64> = workers.into_iter().map(|w| w.join().unwrap()).collect();
    assert!(values.windows(2).all(|w| w[0] == w[1]), "concurrent answers must agree");
    println!("\n6 concurrent stream_greedi queries -> all agree on f(S) = {:.5}", values[0]);

    // ---- drift: pull more of the stream in, version bumps -----------------
    let adv = client.advance(None, 400).expect("advance");
    println!("\nadvance 400 -> {}", adv.dump());
    let after = client.query("greedi", None, &qspec).expect("query after drift");
    println!(
        "query on v{} -> f(S) = {:.5} (corpus drifted, same wire spec)",
        after.dataset_version, after.value
    );

    // ---- the latency surface ---------------------------------------------
    let stats = client.stats().expect("stats");
    let lat = stats.get("latency").unwrap();
    println!("\nstats.latency -> {}", lat.dump());

    let _ = client.shutdown().expect("shutdown");
    server.join();
    println!("\ndaemon stopped cleanly");
}
