//! Exemplar-based clustering (paper §3.4.2 / §6.1): select k representative
//! images from a tiny-image-like corpus with GreeDi, compare every protocol
//! through the unified registry, and report cluster occupancy for the
//! winning exemplars.
//!
//! ```sh
//! cargo run --release --example exemplar_clustering -- --n 5000 --k 50 --m 10 [--local] [--threads 4]
//! ```

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::util::args::Args;
use greedi::util::table::Table;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 5_000);
    let k = args.get_usize("k", 50);
    let m = args.get_usize("m", 10);
    let threads = args.get_usize("threads", 1);
    let local = args.has_flag("local");
    let seed = args.get_u64("seed", 7);

    println!("== exemplar clustering: n={n}, d=32, k={k}, m={m}, local={local} ==\n");
    let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 32), seed));
    let problem = FacilityProblem::new(&data);

    let mut spec = RunSpec::new(m, k).threads(threads).seed(seed);
    if local {
        spec = spec.local();
    }

    let central = protocol::by_name("centralized").expect("registry").run(&problem, &spec);
    let mut t = Table::new(
        "protocol comparison",
        &["protocol", "f(S)", "ratio", "oracle calls", "sim time"],
    );
    t.row(&[
        "centralized".into(),
        format!("{:.5}", central.value),
        "1.000".into(),
        central.oracle_calls.to_string(),
        format!("{:.3}s", central.sim_time()),
    ]);

    let grd = protocol::by_name("greedi").expect("registry").run(&problem, &spec);
    t.row(&[
        "greedi".into(),
        format!("{:.5}", grd.value),
        format!("{:.3}", grd.ratio_vs(central.value)),
        grd.oracle_calls.to_string(),
        format!("{:.3}s", grd.sim_time()),
    ]);
    for name in protocol::BASELINE_NAMES {
        let r = protocol::by_name(name).expect("registry").run(&problem, &spec);
        t.row(&[
            r.name.clone(),
            format!("{:.5}", r.value),
            format!("{:.3}", r.ratio_vs(central.value)),
            r.oracle_calls.to_string(),
            format!("{:.3}s", r.sim_time()),
        ]);
    }
    t.print();

    // Cluster occupancy under the GreeDi exemplars.
    let mut counts = vec![0usize; grd.solution.len()];
    for v in 0..data.n {
        let mut best = (f64::INFINITY, 0usize);
        for (ci, &e) in grd.solution.iter().enumerate() {
            let d2 = data.sqdist(v, e);
            if d2 < best.0 {
                best = (d2, ci);
            }
        }
        counts[best.1] += 1;
    }
    println!("\nGreeDi exemplars (id ← assigned points):");
    for (ci, (&e, &c)) in grd.solution.iter().zip(&counts).enumerate().take(16) {
        println!("  #{ci:<3} element {e:<6} ← {c} points");
    }
    if grd.solution.len() > 16 {
        println!("  … ({} exemplars total)", grd.solution.len());
    }
}
