//! Quickstart: the smallest end-to-end run of the unified protocol API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a clustered point set, builds ONE `RunSpec`, and drives every
//! registered distributed protocol (plus the centralized reference) through
//! `protocol::by_name` — the paper's whole §6 comparison in a dozen lines.

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};

fn main() {
    let (n, m, k) = (2_000, 8, 20);
    println!("== GreeDi quickstart: n={n} points, m={m} machines, k={k} exemplars ==\n");

    // 1. data — tiny-image-like clustered vectors (paper §6.1 preprocessing)
    let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), 42));

    // 2. problem — exemplar clustering (k-medoid via submodular f, §3.4.2)
    let problem = FacilityProblem::new(&data);

    // 3. one spec for every protocol: same budgets, partition, seed, threads
    let spec = RunSpec::new(m, k).threads(2).seed(42);

    // 4. centralized reference (impractical at real scale — the baseline)
    let central = protocol::by_name("centralized")
        .expect("registry")
        .run(&problem, &spec);
    println!("centralized : {}", central.one_line());

    // 5. sweep the registry — GreeDi, tree reduction, naive baselines,
    //    GreedyScaling — all under the identical spec
    let mut greedi = None;
    for name in protocol::NAMES {
        if name == "centralized" {
            continue;
        }
        let run = protocol::by_name(name).expect("registry").run(&problem, &spec);
        println!(
            "{name:<13}: ratio={:.4}  {}",
            run.ratio_vs(central.value),
            run.one_line()
        );
        if name == "greedi" {
            greedi = Some(run);
        }
    }

    let greedi = greedi.expect("greedi in registry");
    println!(
        "\nheadline ratio = {:.4}  (paper reports ≈0.98 for exemplar clustering)",
        greedi.ratio_vs(central.value)
    );
    println!(
        "communication: {} element ids shuffled (vs n = {n} for data-parallel greedy)",
        greedi.job.shuffled_elements
    );
}
