//! Quickstart: the smallest end-to-end GreeDi run.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a clustered point set, runs the centralized lazy greedy and
//! the two-round GreeDi protocol on the exemplar-clustering objective, and
//! prints the paper's headline metric (distributed/centralized ratio).

use std::sync::Arc;

use greedi::coordinator::greedi::{centralized, Greedi, GreediConfig};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};

fn main() {
    let (n, m, k) = (2_000, 8, 20);
    println!("== GreeDi quickstart: n={n} points, m={m} machines, k={k} exemplars ==\n");

    // 1. data — tiny-image-like clustered vectors (paper §6.1 preprocessing)
    let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), 42));

    // 2. problem — exemplar clustering (k-medoid via submodular f, §3.4.2)
    let problem = FacilityProblem::new(&data);

    // 3. centralized reference (impractical at real scale — the baseline)
    let central = centralized(&problem, k, "lazy", 42);
    println!("centralized : {}", central.one_line());

    // 4. GreeDi — two MapReduce rounds, m machines
    let run = Greedi::new(GreediConfig::new(m, k)).run(&problem, 42);
    println!("greedi      : {}", run.one_line());

    println!(
        "\nratio = {:.4}  (paper reports ≈0.98 for exemplar clustering)",
        run.ratio_vs(central.value)
    );
    println!(
        "communication: {} element ids shuffled (vs n = {n} for data-parallel greedy)",
        run.job.shuffled_elements
    );
}
